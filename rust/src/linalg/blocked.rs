//! Blocked dense SPD solve engine.
//!
//! The GRAIL ridge system `B = G_PHᵀ (G_PP + λI)⁻¹` is solved once per
//! site, and at depth the per-site solve is the dominant serial cost of
//! the (now O(L)) closed loop. The scalar triple-loop factorization in
//! [`super::Cholesky`] and its column-at-a-time `solve_multi` leave all
//! of the available locality on the table, so this module supplies the
//! production path:
//!
//! - **Right-looking panel Cholesky** ([`BlockedCholesky::factor`]):
//!   a narrow panel is factored with the scalar kernel, and the O(n³)
//!   trailing update runs through the shared GEMM kernels
//!   ([`ops::gemm_nt_acc_f64`]) in cache-sized column blocks.
//! - **Blocked TRSM** — forward/back substitution processes all right-
//!   hand sides in column panels ([`RHS_PANEL`] wide): the inner loops
//!   are contiguous panel-row axpys plus GEMM panel updates
//!   ([`ops::gemm_acc_f64`] / [`ops::gemm_tn_acc_f64`]) instead of one
//!   strided column extraction per `solve_vec` call.
//! - **Parallel RHS fan-out** — panels are independent and write
//!   disjoint output columns, so [`BlockedCholesky::solve_multi`] fans
//!   them over [`run_grid`] workers once the system is big enough.
//!   Per-panel arithmetic never depends on the worker count, so results
//!   are bit-identical at any parallelism (the staged/rescan equality
//!   contract in `rust/tests/staged.rs` relies on this).
//!
//! Everything runs in f64 internally (same precision as the scalar
//! reference, which stays available as
//! [`super::solve_spd_multi_ref`] for equivalence tests); only the
//! summation order differs.

use crate::coordinator::scheduler::{audit::WriteSet, default_threads, run_grid};
use crate::tensor::{ops, Tensor};
use anyhow::{bail, Result};

/// Panel width of the right-looking factorization. Sized so one panel
/// (`n × FACTOR_BLOCK` of f64) stays L2-resident for the Gram sizes the
/// pipeline produces (n up to ~1k).
pub const FACTOR_BLOCK: usize = 48;

/// Column-panel width of the multi-RHS substitution: the in-flight
/// panel (`n × RHS_PANEL` of f64) is the working set of both sweeps.
pub const RHS_PANEL: usize = 32;

/// Minimum substitution flop volume (≈ `2·n²·m`) before `solve_multi`
/// fans RHS panels over worker threads; below this the scoped-thread
/// spawn overhead dominates the solve itself.
const PARALLEL_MIN_FLOPS: f64 = 4e6;

/// Lower-triangular Cholesky factor `L` with `L·Lᵀ = A` (A symmetric
/// positive definite), factored by panels. Stored dense row-major in
/// f64 with the strict upper triangle zeroed.
pub struct BlockedCholesky {
    n: usize,
    l: Vec<f64>,
}

impl BlockedCholesky {
    /// Factor `a` (must be square & SPD). Fails on non-positive pivots.
    pub fn factor(a: &Tensor) -> Result<Self> {
        let n = a.dim(0);
        if a.dim(1) != n {
            bail!("cholesky: matrix not square: {:?}", a.shape());
        }
        let mut l = vec![0.0f64; n * n];
        load_lower(a.data(), &mut l, n, 0.0);
        factor_in_place(&mut l, n)?;
        Ok(BlockedCholesky { n, l })
    }

    /// Factor with escalating diagonal jitter: tries `a`, then
    /// `a + jitter·scale·I` with jitter ∈ {1e-8, 1e-6, ...} where
    /// `scale` is the mean diagonal. One work buffer is reused across
    /// retries, and the final error reports the first pivot failure.
    pub fn factor_jittered(a: &Tensor) -> Result<Self> {
        let n = a.dim(0);
        if a.dim(1) != n {
            bail!("cholesky: matrix not square: {:?}", a.shape());
        }
        let mut l = vec![0.0f64; n * n];
        load_lower(a.data(), &mut l, n, 0.0);
        let first_err = match factor_in_place(&mut l, n) {
            Ok(()) => return Ok(BlockedCholesky { n, l }),
            Err(e) => e,
        };
        // Jitter is computed in f32 to mirror the scalar reference
        // (`add_diag` on the f32 matrix), so both paths escalate through
        // identical retry matrices.
        let scale = super::mean_diag(a).abs().max(1e-12);
        for e in [1e-8f32, 1e-6, 1e-4, 1e-2, 1.0] {
            load_lower(a.data(), &mut l, n, e * scale);
            if factor_in_place(&mut l, n).is_ok() {
                return Ok(BlockedCholesky { n, l });
            }
        }
        bail!("cholesky: matrix not factorizable even with jitter (first failure: {first_err})")
    }

    /// System size `n`.
    pub fn size(&self) -> usize {
        self.n
    }

    /// Solve `A x = b` for one right-hand side (single-panel path — no
    /// worker fan-out, cheap enough for the OBS inner loops).
    pub fn solve_vec(&self, b: &[f32]) -> Vec<f32> {
        assert_eq!(b.len(), self.n);
        let mut y: Vec<f64> = b.iter().map(|&v| v as f64).collect();
        solve_panel(&self.l, self.n, &mut y, 1);
        y.iter().map(|&v| v as f32).collect()
    }

    /// Solve `A X = B` where `b: [n, m]` holds the right-hand sides as
    /// columns (*rows are equations*): returns `X: [n, m]`. RHS panels
    /// run on scheduler workers when the system is large enough.
    pub fn solve_multi(&self, b: &Tensor) -> Tensor {
        self.solve_multi_with(b, 0)
    }

    /// [`solve_multi`](Self::solve_multi) with an explicit worker
    /// count (`0` = auto). The result is bit-identical for every
    /// `workers` value: panels are computed independently and written
    /// to disjoint output columns.
    pub fn solve_multi_with(&self, b: &Tensor, workers: usize) -> Tensor {
        let (n, m) = (self.n, b.dim(1));
        assert_eq!(b.dim(0), n, "rhs rows must match system size");
        let panels = self.solve_panels(b, workers);
        let mut out = Tensor::zeros(&[n, m]);
        let od = out.data_mut();
        for ((c0, pw), y) in panels {
            for i in 0..n {
                let row = &y[i * pw..(i + 1) * pw];
                let dst = &mut od[i * m + c0..i * m + c0 + pw];
                for (d, &v) in dst.iter_mut().zip(row) {
                    *d = v as f32;
                }
            }
        }
        out
    }

    /// Solve `A X = B` and return `Xᵀ: [m, n]` directly — each solved
    /// panel is transposed while still cache-resident, so callers that
    /// want the transposed solution (the ridge reconstruction's
    /// `B = Zᵀ`) never pay a full-matrix transpose copy.
    pub fn solve_multi_t(&self, b: &Tensor) -> Tensor {
        self.solve_multi_t_with(b, 0)
    }

    /// [`solve_multi_t`](Self::solve_multi_t) with an explicit worker
    /// count (`0` = auto) — bit-identical at every `workers` value.
    pub fn solve_multi_t_with(&self, b: &Tensor, workers: usize) -> Tensor {
        let (n, m) = (self.n, b.dim(1));
        assert_eq!(b.dim(0), n, "rhs rows must match system size");
        let panels = self.solve_panels(b, workers);
        let mut out = Tensor::zeros(&[m, n]);
        let od = out.data_mut();
        for ((c0, pw), y) in panels {
            for j in 0..pw {
                let dst = &mut od[(c0 + j) * n..(c0 + j + 1) * n];
                for (i, d) in dst.iter_mut().enumerate() {
                    *d = y[i * pw + j] as f32;
                }
            }
        }
        out
    }

    /// Solve every RHS panel, in parallel when worthwhile. Returns
    /// `((c0, pw), solved panel)` in ascending `c0` order.
    #[allow(clippy::type_complexity)]
    fn solve_panels(&self, b: &Tensor, workers: usize) -> Vec<((usize, usize), Vec<f64>)> {
        let (n, m) = (self.n, b.dim(1));
        let mut jobs: Vec<(usize, usize)> = Vec::new();
        let mut c0 = 0;
        while c0 < m {
            let pw = RHS_PANEL.min(m - c0);
            jobs.push((c0, pw));
            c0 += pw;
        }
        let flops = 2.0 * (n as f64) * (n as f64) * (m as f64);
        let threads = if workers != 0 {
            workers
        } else if flops < PARALLEL_MIN_FLOPS {
            1
        } else {
            // `default_threads` is the scheduler's divided thread
            // budget: auto-sized solves inside an already-parallel
            // fan-out get that worker's share — typically serial —
            // same policy as the packed GEMM engine. Scheduling only:
            // the result is bit-identical either way.
            default_threads()
        };
        // Each job owns the RHS columns `[c0, c0 + pw)` exclusively;
        // the write-set auditor asserts the panels tile `0..m` in both
        // the serial and the parallel branch (debug/audit builds only).
        let ws = WriteSet::new("blocked-solver RHS panels", m);
        if threads <= 1 || jobs.len() <= 1 {
            let out: Vec<((usize, usize), Vec<f64>)> = jobs
                .into_iter()
                .enumerate()
                .map(|(ji, (c0, pw))| {
                    ws.claim(ji, c0, pw);
                    ((c0, pw), self.solve_one_panel(b, c0, pw))
                })
                .collect();
            ws.verify();
            return out;
        }
        let solved = run_grid(jobs.clone(), threads, |ji, &(c0, pw)| {
            ws.claim(ji, c0, pw);
            self.solve_one_panel(b, c0, pw)
        });
        ws.verify();
        jobs.into_iter().zip(solved).collect()
    }

    /// Pack RHS columns `[c0, c0+pw)` into an `[n, pw]` f64 panel and
    /// run both substitution sweeps on it.
    fn solve_one_panel(&self, b: &Tensor, c0: usize, pw: usize) -> Vec<f64> {
        let (n, m) = (self.n, b.dim(1));
        let bd = b.data();
        let mut y = vec![0.0f64; n * pw];
        for i in 0..n {
            let src = &bd[i * m + c0..i * m + c0 + pw];
            let dst = &mut y[i * pw..(i + 1) * pw];
            for (d, &v) in dst.iter_mut().zip(src) {
                *d = v as f64;
            }
        }
        solve_panel(&self.l, n, &mut y, pw);
        y
    }

    /// log-determinant of A (2·Σ log Lᵢᵢ) — used by tests/diagnostics.
    pub fn logdet(&self) -> f64 {
        (0..self.n).map(|i| self.l[i * self.n + i].ln()).sum::<f64>() * 2.0
    }
}

/// Copy the lower triangle of the f32 matrix `src` (n×n row-major)
/// into the f64 work buffer, zero the strict upper triangle, and add
/// `jitter` to the diagonal *in f32* (matching the scalar reference's
/// `add_diag`-then-widen semantics). Overwrites every entry, so the
/// buffer can be reused across jitter retries.
fn load_lower(src: &[f32], dst: &mut [f64], n: usize, jitter: f32) {
    for i in 0..n {
        let row = &mut dst[i * n..(i + 1) * n];
        let srow = &src[i * n..(i + 1) * n];
        for j in 0..i {
            row[j] = srow[j] as f64;
        }
        row[i] = (srow[i] + jitter) as f64;
        row[i + 1..].fill(0.0);
    }
}

/// Right-looking panel factorization of the lower triangle of `l`
/// (n×n row-major f64), in place. On success `l` holds `L` with the
/// strict upper triangle zeroed; fails on the first non-positive pivot.
fn factor_in_place(l: &mut [f64], n: usize) -> Result<()> {
    let nb = FACTOR_BLOCK;
    // Reused packed copy of the sub-diagonal panel (trailing rows ×
    // panel width) — gives the GEMM kernels contiguous operands and
    // sidesteps aliasing with the trailing destination.
    let mut panel: Vec<f64> = Vec::new();
    let mut j0 = 0;
    while j0 < n {
        let jb = nb.min(n - j0);
        let trail = j0 + jb;
        // 1. Scalar factor of the diagonal block (its entries already
        //    carry every previous panel's trailing update).
        for i in j0..trail {
            for j in j0..=i {
                let mut s = l[i * n + j];
                for k in j0..j {
                    s -= l[i * n + k] * l[j * n + k];
                }
                if i == j {
                    if s <= 0.0 || !s.is_finite() {
                        bail!("cholesky: non-positive pivot {s:.3e} at {i}");
                    }
                    l[i * n + i] = s.sqrt();
                } else {
                    l[i * n + j] = s / l[j * n + j];
                }
            }
        }
        // 2. Panel TRSM: rows below the block solve `X · L11ᵀ = A21`.
        for i in trail..n {
            for j in j0..trail {
                let mut s = l[i * n + j];
                for k in j0..j {
                    s -= l[i * n + k] * l[j * n + k];
                }
                l[i * n + j] = s / l[j * n + j];
            }
        }
        // 3. Trailing update `A22 -= P·Pᵀ` through the GEMM kernel, in
        //    column blocks over the lower triangle.
        if trail < n {
            let m_trail = n - trail;
            panel.clear();
            panel.reserve(m_trail * jb);
            for i in trail..n {
                panel.extend_from_slice(&l[i * n + j0..i * n + j0 + jb]);
            }
            let mut c0 = trail;
            while c0 < n {
                let cb = nb.min(n - c0);
                let a_off = (c0 - trail) * jb;
                ops::gemm_nt_acc_f64(
                    &panel[a_off..],
                    jb,
                    &panel[a_off..a_off + cb * jb],
                    jb,
                    &mut l[c0 * n + c0..],
                    n,
                    n - c0,
                    jb,
                    cb,
                    -1.0,
                );
                c0 += cb;
            }
        }
        j0 = trail;
    }
    // The trailing updates touched a few upper-triangle entries inside
    // diagonal blocks; scrub them so `l` is a clean lower factor.
    for i in 0..n {
        l[i * n + i + 1..(i + 1) * n].fill(0.0);
    }
    Ok(())
}

/// Blocked forward + back substitution of `L·Lᵀ·X = Y` on one packed
/// column panel `y` (`[n, pw]` row-major f64, solved in place).
fn solve_panel(l: &[f64], n: usize, y: &mut [f64], pw: usize) {
    let nb = FACTOR_BLOCK;
    // Forward sweep: L z = y.
    let mut i0 = 0;
    while i0 < n {
        let ib = nb.min(n - i0);
        // Diagonal block: scalar forward solve over contiguous rows.
        for i in i0..i0 + ib {
            let (above, cur) = y.split_at_mut(i * pw);
            let yi = &mut cur[..pw];
            for k in i0..i {
                let c = l[i * n + k];
                if c != 0.0 {
                    let yk = &above[k * pw..(k + 1) * pw];
                    for (v, &u) in yi.iter_mut().zip(yk) {
                        *v -= c * u;
                    }
                }
            }
            let d = l[i * n + i];
            for v in yi.iter_mut() {
                *v /= d;
            }
        }
        // Rows below the block absorb it in one GEMM panel update.
        if i0 + ib < n {
            let (top, bottom) = y.split_at_mut((i0 + ib) * pw);
            ops::gemm_acc_f64(
                &l[(i0 + ib) * n + i0..],
                n,
                &top[i0 * pw..],
                pw,
                bottom,
                pw,
                n - i0 - ib,
                ib,
                pw,
                -1.0,
            );
        }
        i0 += ib;
    }
    // Back sweep: Lᵀ x = z, bottom-up.
    let mut i1 = n;
    while i1 > 0 {
        let ib = nb.min(i1);
        let i0 = i1 - ib;
        // Contributions of the already-solved rows below this block,
        // applied through the transposed GEMM kernel.
        if i1 < n {
            let (top, bottom) = y.split_at_mut(i1 * pw);
            ops::gemm_tn_acc_f64(
                &l[i1 * n + i0..],
                n,
                bottom,
                pw,
                &mut top[i0 * pw..],
                pw,
                ib,
                n - i1,
                pw,
                -1.0,
            );
        }
        // Diagonal block: scalar back solve (Lᵀ is upper-triangular).
        for i in (i0..i1).rev() {
            let (cur, below) = y.split_at_mut((i + 1) * pw);
            let yi = &mut cur[i * pw..];
            for k in (i + 1)..i1 {
                let c = l[k * n + i];
                if c != 0.0 {
                    let xk = &below[(k - i - 1) * pw..(k - i) * pw];
                    for (v, &u) in yi.iter_mut().zip(xk) {
                        *v -= c * u;
                    }
                }
            }
            let d = l[i * n + i];
            for v in yi.iter_mut() {
                *v /= d;
            }
        }
        i1 = i0;
    }
}

/// Solve `A x = b` (SPD `A`), with jitter fallback.
pub fn solve_spd(a: &Tensor, b: &[f32]) -> Result<Vec<f32>> {
    Ok(BlockedCholesky::factor_jittered(a)?.solve_vec(b))
}

/// Solve `A X = B` (SPD `A`, `B: [n,m]`) with the blocked engine, with
/// jitter fallback. Panics only on shape errors; numerical failure
/// falls back to jitter and is practically unreachable for `G + λI`
/// with λ > 0.
pub fn solve_spd_multi(a: &Tensor, b: &Tensor) -> Tensor {
    BlockedCholesky::factor_jittered(a)
        .expect("SPD solve failed even with jitter")
        .solve_multi(b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{solve_spd_multi_ref, Cholesky};
    use crate::rng::Pcg64;
    use crate::tensor::ops::{gram, matmul};

    fn randn(r: &mut Pcg64, shape: &[usize]) -> Tensor {
        let mut t = Tensor::zeros(shape);
        r.fill_normal(t.data_mut(), 1.0);
        t
    }

    fn spd(r: &mut Pcg64, n: usize) -> Tensor {
        let x = randn(r, &[2 * n + 3, n]);
        let mut g = gram(&x);
        super::super::add_diag(&mut g, 1.0);
        g
    }

    #[test]
    fn factor_solve_residual_small() {
        let mut r = Pcg64::seed(31);
        for &n in &[1usize, 5, 47, 48, 49, 130] {
            let a = spd(&mut r, n);
            let b = randn(&mut r, &[n, 7]);
            let x = BlockedCholesky::factor(&a).unwrap().solve_multi(&b);
            let ax = matmul(&a, &x);
            let scale = a.frobenius().max(1.0);
            let res = ax.max_abs_diff(&b);
            assert!(res < 1e-3 * scale, "n={n}: residual {res}");
        }
    }

    #[test]
    fn matches_scalar_reference_across_block_boundaries() {
        let mut r = Pcg64::seed(32);
        // Below, at, and above FACTOR_BLOCK, plus multi-panel sizes.
        for &n in &[3usize, FACTOR_BLOCK - 1, FACTOR_BLOCK, FACTOR_BLOCK + 1, 100] {
            for &m in &[1usize, RHS_PANEL, RHS_PANEL + 5] {
                let a = spd(&mut r, n);
                let b = randn(&mut r, &[n, m]);
                let fast = solve_spd_multi(&a, &b);
                let slow = solve_spd_multi_ref(&a, &b);
                let diff = fast.max_abs_diff(&slow);
                assert!(diff < 1e-4, "n={n} m={m}: {diff}");
            }
        }
    }

    #[test]
    fn transposed_solve_is_transpose() {
        let mut r = Pcg64::seed(33);
        let a = spd(&mut r, 70);
        let b = randn(&mut r, &[70, 37]);
        let chol = BlockedCholesky::factor(&a).unwrap();
        let x = chol.solve_multi(&b);
        let xt = chol.solve_multi_t(&b);
        assert_eq!(xt.shape(), &[37, 70]);
        for i in 0..70 {
            for j in 0..37 {
                assert_eq!(x.at2(i, j).to_bits(), xt.at2(j, i).to_bits(), "({i},{j})");
            }
        }
    }

    #[test]
    fn worker_count_does_not_change_bits() {
        let mut r = Pcg64::seed(34);
        let a = spd(&mut r, 96);
        let b = randn(&mut r, &[96, 200]);
        let chol = BlockedCholesky::factor(&a).unwrap();
        let base = chol.solve_multi_with(&b, 1);
        for workers in [2usize, 3, 8] {
            let x = chol.solve_multi_with(&b, workers);
            assert_eq!(base, x, "workers={workers}");
        }
    }

    #[test]
    fn solve_vec_matches_multi_column() {
        let mut r = Pcg64::seed(35);
        let a = spd(&mut r, 60);
        let b = randn(&mut r, &[60, 3]);
        let chol = BlockedCholesky::factor(&a).unwrap();
        let x = chol.solve_multi(&b);
        for j in 0..3 {
            let col: Vec<f32> = (0..60).map(|i| b.at2(i, j)).collect();
            let xj = chol.solve_vec(&col);
            for i in 0..60 {
                assert!((x.at2(i, j) - xj[i]).abs() < 1e-5, "({i},{j})");
            }
        }
    }

    #[test]
    fn jitter_rescues_rank_deficient_gram() {
        // N < H Gram: plain factor fails, jitter works — and the
        // rescued solve stays close to the scalar reference.
        let mut r = Pcg64::seed(36);
        let x = randn(&mut r, &[5, 12]);
        let g = gram(&x);
        let err = BlockedCholesky::factor(&g).unwrap_err().to_string();
        assert!(err.contains("pivot"), "{err}");
        let chol = BlockedCholesky::factor_jittered(&g).unwrap();
        assert!(chol.logdet().is_finite());
        let b = randn(&mut r, &[12, 4]);
        let fast = chol.solve_multi(&b);
        let slow = solve_spd_multi_ref(&g, &b);
        assert!(fast.all_finite() && slow.all_finite());
    }

    #[test]
    fn jitter_failure_reports_first_error() {
        // A matrix with a negative diagonal that no jitter level fixes.
        let a = Tensor::from_vec(&[2, 2], vec![-1e9, 0.0, 0.0, -1e9]);
        let err = BlockedCholesky::factor_jittered(&a).unwrap_err().to_string();
        assert!(err.contains("first failure"), "{err}");
        assert!(err.contains("pivot"), "{err}");
    }

    #[test]
    fn logdet_matches_scalar() {
        let mut r = Pcg64::seed(37);
        let a = spd(&mut r, 64);
        let fast = BlockedCholesky::factor(&a).unwrap().logdet();
        let slow = Cholesky::factor(&a).unwrap().logdet();
        assert!((fast - slow).abs() < 1e-6 * (1.0 + slow.abs()), "{fast} vs {slow}");
    }

    #[test]
    fn empty_and_unit_systems() {
        let a = Tensor::eye(1);
        let b = Tensor::from_vec(&[1, 1], vec![4.0]);
        let x = BlockedCholesky::factor(&a).unwrap().solve_multi(&b);
        assert_eq!(x.data(), &[4.0]);
        let e = Tensor::zeros(&[0, 0]);
        let eb = Tensor::zeros(&[0, 3]);
        let x = BlockedCholesky::factor(&e).unwrap().solve_multi(&eb);
        assert_eq!(x.shape(), &[0, 3]);
    }
}
