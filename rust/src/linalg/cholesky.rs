//! Scalar Cholesky factorization and SPD solves (f64 internal
//! precision) — the *reference* implementation.
//!
//! Gram matrices from short calibration runs are frequently
//! near-singular (N < H or strongly correlated channels); the paper
//! handles this with the ridge term. We additionally retry with
//! escalating diagonal jitter if the factorization still breaks down,
//! mirroring standard practice.
//!
//! The production solve path is the blocked engine in
//! [`super::BlockedCholesky`]; this scalar triple-loop version stays as
//! the independently-simple oracle behind
//! [`solve_spd_multi_ref`] that the equivalence tests
//! (`rust/tests/blocked_solver.rs`) compare against.

use crate::tensor::Tensor;
use anyhow::{anyhow, bail, Result};

/// Lower-triangular Cholesky factor `L` with `L·Lᵀ = A` (A symmetric
/// positive definite). Stored dense row-major in f64.
pub struct Cholesky {
    n: usize,
    l: Vec<f64>,
}

impl Cholesky {
    /// Factor `a` (must be square & SPD). Fails on non-positive pivots.
    pub fn factor(a: &Tensor) -> Result<Self> {
        let n = a.dim(0);
        if a.dim(1) != n {
            bail!("cholesky: matrix not square: {:?}", a.shape());
        }
        let mut l = vec![0.0f64; n * n];
        factor_into(a.data(), n, 0.0, &mut l)?;
        Ok(Cholesky { n, l })
    }

    /// Factor with escalating diagonal jitter: tries `a`, then
    /// `a + jitter·scale·I` with jitter ∈ {1e-8, 1e-6, ...} where
    /// `scale` is the mean diagonal. The factor buffer is allocated
    /// once and reused across retries, and the final error reports the
    /// *first* pivot failure (the informative one — later retries fail
    /// on increasingly perturbed matrices).
    pub fn factor_jittered(a: &Tensor) -> Result<Self> {
        let n = a.dim(0);
        if a.dim(1) != n {
            bail!("cholesky: matrix not square: {:?}", a.shape());
        }
        let mut l = vec![0.0f64; n * n];
        let first_err = match factor_into(a.data(), n, 0.0, &mut l) {
            Ok(()) => return Ok(Cholesky { n, l }),
            Err(e) => e,
        };
        let scale = super::mean_diag(a).abs().max(1e-12);
        for e in [1e-8f32, 1e-6, 1e-4, 1e-2, 1.0] {
            if factor_into(a.data(), n, e * scale, &mut l).is_ok() {
                return Ok(Cholesky { n, l });
            }
        }
        bail!("cholesky: matrix not factorizable even with jitter (first failure: {first_err})")
    }

    /// Solve `A x = b` for one right-hand side.
    pub fn solve_vec(&self, b: &[f32]) -> Vec<f32> {
        assert_eq!(b.len(), self.n);
        let n = self.n;
        let l = &self.l;
        // Forward substitution L y = b.
        let mut y = vec![0.0f64; n];
        for i in 0..n {
            let mut s = b[i] as f64;
            for k in 0..i {
                s -= l[i * n + k] * y[k];
            }
            y[i] = s / l[i * n + i];
        }
        // Back substitution Lᵀ x = y.
        let mut x = vec![0.0f64; n];
        for ii in 0..n {
            let i = n - 1 - ii;
            let mut s = y[i];
            for k in (i + 1)..n {
                s -= l[k * n + i] * x[k];
            }
            x[i] = s / l[i * n + i];
        }
        x.iter().map(|v| *v as f32).collect()
    }

    /// Solve `A X = B` column-by-column where `b: [n, m]` holds the
    /// right-hand sides as *rows are equations*: returns `X: [n, m]`.
    /// O(n²) per column with strided extraction — the blocked engine's
    /// panel TRSM replaces this on the hot path.
    pub fn solve_multi(&self, b: &Tensor) -> Tensor {
        let n = self.n;
        assert_eq!(b.dim(0), n, "rhs rows must match system size");
        let m = b.dim(1);
        let mut out = Tensor::zeros(&[n, m]);
        let mut col = vec![0.0f32; n];
        for j in 0..m {
            for i in 0..n {
                col[i] = b.at2(i, j);
            }
            let x = self.solve_vec(&col);
            for i in 0..n {
                out.set2(i, j, x[i]);
            }
        }
        out
    }

    /// log-determinant of A (2·Σ log Lᵢᵢ) — used by tests/diagnostics.
    pub fn logdet(&self) -> f64 {
        (0..self.n).map(|i| self.l[i * self.n + i].ln()).sum::<f64>() * 2.0
    }
}

/// Scalar left-looking factorization of `a + jitter·I` into `l`
/// (overwritten in full, so one buffer serves every jitter retry). The
/// jitter is added in f32 — identical retry matrices to the old
/// clone-then-`add_diag` path and to the blocked engine.
fn factor_into(ad: &[f32], n: usize, jitter: f32, l: &mut [f64]) -> Result<()> {
    l.fill(0.0);
    for i in 0..n {
        for j in 0..=i {
            let mut s = if i == j {
                (ad[i * n + i] + jitter) as f64
            } else {
                ad[i * n + j] as f64
            };
            for k in 0..j {
                s -= l[i * n + k] * l[j * n + k];
            }
            if i == j {
                if s <= 0.0 || !s.is_finite() {
                    return Err(anyhow!("cholesky: non-positive pivot {s:.3e} at {i}"));
                }
                l[i * n + i] = s.sqrt();
            } else {
                l[i * n + j] = s / l[j * n + j];
            }
        }
    }
    Ok(())
}

/// Solve `A X = B` (SPD `A`, `B: [n,m]`) with the scalar reference
/// solver, with jitter fallback. Kept for tolerance-based equivalence
/// tests against the blocked engine
/// ([`super::solve_spd_multi`]); not a hot path.
pub fn solve_spd_multi_ref(a: &Tensor, b: &Tensor) -> Tensor {
    Cholesky::factor_jittered(a)
        .expect("SPD solve failed even with jitter")
        .solve_multi(b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;
    use crate::tensor::ops::{gram, matmul};

    fn spd(r: &mut Pcg64, n: usize) -> Tensor {
        // XᵀX + I with X taller than wide is comfortably SPD.
        let mut x = Tensor::zeros(&[2 * n + 3, n]);
        r.fill_normal(x.data_mut(), 1.0);
        let mut g = gram(&x);
        super::super::add_diag(&mut g, 1.0);
        g
    }

    #[test]
    fn factor_and_reconstruct() {
        let mut r = Pcg64::seed(21);
        let a = spd(&mut r, 7);
        let c = Cholesky::factor(&a).unwrap();
        // L Lᵀ == A
        let n = 7;
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += c.l[i * n + k] * c.l[j * n + k];
                }
                assert!((s - a.at2(i, j) as f64).abs() < 1e-4, "({i},{j})");
            }
        }
    }

    #[test]
    fn solve_residual_small() {
        let mut r = Pcg64::seed(22);
        let a = spd(&mut r, 12);
        let b: Vec<f32> = (0..12).map(|i| (i as f32).sin()).collect();
        let x = Cholesky::factor_jittered(&a).unwrap().solve_vec(&b);
        let xt = Tensor::from_vec(&[12, 1], x);
        let ax = matmul(&a, &xt);
        for i in 0..12 {
            assert!((ax.at2(i, 0) - b[i]).abs() < 1e-3, "row {i}");
        }
    }

    #[test]
    fn solve_multi_matches_vec() {
        let mut r = Pcg64::seed(23);
        let a = spd(&mut r, 9);
        let mut b = Tensor::zeros(&[9, 4]);
        r.fill_normal(b.data_mut(), 1.0);
        let x = solve_spd_multi_ref(&a, &b);
        let c = Cholesky::factor(&a).unwrap();
        for j in 0..4 {
            let col: Vec<f32> = (0..9).map(|i| b.at2(i, j)).collect();
            let xj = c.solve_vec(&col);
            for i in 0..9 {
                assert!((x.at2(i, j) - xj[i]).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn non_spd_fails_then_jitter_rescues() {
        // Rank-deficient Gram (N < H): plain factor fails, jitter works.
        let mut r = Pcg64::seed(24);
        let mut x = Tensor::zeros(&[3, 8]);
        r.fill_normal(x.data_mut(), 1.0);
        let g = gram(&x);
        assert!(Cholesky::factor(&g).is_err());
        let c = Cholesky::factor_jittered(&g).unwrap();
        assert!(c.logdet().is_finite());
    }

    #[test]
    fn hopeless_matrix_reports_first_failure() {
        // Strongly negative diagonal: every jitter level fails, and the
        // final error must carry the first (unjittered) pivot message.
        let a = Tensor::from_vec(&[2, 2], vec![-1e9, 0.0, 0.0, -1e9]);
        let err = Cholesky::factor_jittered(&a).unwrap_err().to_string();
        assert!(err.contains("not factorizable"), "{err}");
        assert!(err.contains("first failure"), "{err}");
        assert!(err.contains("pivot"), "{err}");
    }

    #[test]
    fn identity_solve_is_identity() {
        let a = Tensor::eye(5);
        let b: Vec<f32> = vec![1., -2., 3., -4., 5.];
        let x = Cholesky::factor_jittered(&a).unwrap().solve_vec(&b);
        for i in 0..5 {
            assert!((x[i] - b[i]).abs() < 1e-6);
        }
    }
}
