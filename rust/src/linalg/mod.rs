//! Dense linear algebra for the GRAIL ridge systems.
//!
//! The compensation map is `B = G_PH^T (G_PP + λI)^{-1}` (paper §3.1);
//! we never form the inverse — instead we Cholesky-factor the SPD
//! matrix `G_PP + λI` (in f64 for stability) and solve against the
//! right-hand sides. The production factor/solve is the blocked engine
//! ([`BlockedCholesky`]: panel factorization with GEMM trailing
//! updates, panel TRSM over all right-hand sides, parallel RHS
//! fan-out); the scalar [`Cholesky`] stays as the reference oracle
//! behind [`solve_spd_multi_ref`]. k-means (for folding) also lives
//! here.

mod blocked;
mod cholesky;
mod kmeans;

pub use blocked::{solve_spd, solve_spd_multi, BlockedCholesky, FACTOR_BLOCK, RHS_PANEL};
pub use cholesky::{solve_spd_multi_ref, Cholesky};
pub use kmeans::{kmeans, KmeansResult};

use crate::tensor::Tensor;

/// Mean of the diagonal of a square matrix (used for the paper's
/// λ = α · mean diag(G_PP) regularizer scaling).
pub fn mean_diag(g: &Tensor) -> f32 {
    let n = g.dim(0);
    assert_eq!(g.dim(1), n);
    if n == 0 {
        return 0.0;
    }
    let s: f64 = (0..n).map(|i| g.at2(i, i) as f64).sum();
    (s / n as f64) as f32
}

/// Add `lambda` to the diagonal, in place.
pub fn add_diag(g: &mut Tensor, lambda: f32) {
    let n = g.dim(0);
    assert_eq!(g.dim(1), n);
    for i in 0..n {
        let v = g.at2(i, i) + lambda;
        g.set2(i, i, v);
    }
}

/// Solve the ridge system that defines the GRAIL reconstruction:
/// given `g_pp: [K,K]` (reduced Gram), `g_ph: [K,H]` (cross Gram, i.e.
/// `Mᵀ G`), and `lambda`, return `B: [H,K]` with
/// `B = g_phᵀ · (g_pp + λI)^{-1}`.
///
/// Solved with the blocked engine as `(g_pp + λI) Z = g_ph`; each RHS
/// panel is transposed into `B` while cache-resident
/// ([`BlockedCholesky::solve_multi_t`]), so there is no full-matrix
/// transpose+reshape copy at the end.
pub fn ridge_reconstruction(g_pp: &Tensor, g_ph: &Tensor, lambda: f32) -> Tensor {
    ridge_reconstruction_with(g_pp, g_ph, lambda, 0)
}

/// [`ridge_reconstruction`] with an explicit worker count for the RHS
/// panel fan-out (`0` = auto) — the pipeline passes its resolved
/// per-run worker budget so a `workers = 1` spec stays single-threaded
/// through the solves too. Bit-identical at every `workers` value.
pub fn ridge_reconstruction_with(
    g_pp: &Tensor,
    g_ph: &Tensor,
    lambda: f32,
    workers: usize,
) -> Tensor {
    let k = g_pp.dim(0);
    assert_eq!(g_pp.dim(1), k);
    assert_eq!(g_ph.dim(0), k, "g_ph rows must equal K");
    let mut a = g_pp.clone();
    add_diag(&mut a, lambda);
    BlockedCholesky::factor_jittered(&a)
        .expect("SPD ridge solve failed even with jitter")
        .solve_multi_t_with(g_ph, workers) // [H, K] — B directly
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;
    use crate::tensor::ops::{gram, matmul, transpose};

    fn randn(r: &mut Pcg64, shape: &[usize]) -> Tensor {
        let mut t = Tensor::zeros(shape);
        r.fill_normal(t.data_mut(), 1.0);
        t
    }

    #[test]
    fn mean_diag_simple() {
        let g = Tensor::from_vec(&[2, 2], vec![2., 5., 5., 4.]);
        assert_eq!(mean_diag(&g), 3.0);
    }

    #[test]
    fn ridge_identity_gram_recovers_selection() {
        // When G = I (uncorrelated channels), B must be (up to λ shrink)
        // the selection matrix itself — the paper's "recovers classic
        // pruning" property.
        let h = 6;
        let p = [1usize, 4, 5];
        let g = Tensor::eye(h);
        let g_ph = crate::tensor::ops::gather_rows(&g, &p); // [K,H] = Mᵀ G
        let g_pp = crate::tensor::ops::gather_cols(&g_ph, &p); // [K,K]
        let b = ridge_reconstruction(&g_pp, &g_ph, 0.0);
        assert_eq!(b.shape(), &[h, p.len()]);
        for i in 0..h {
            for (kk, &pi) in p.iter().enumerate() {
                let want = if i == pi { 1.0 } else { 0.0 };
                assert!((b.at2(i, kk) - want).abs() < 1e-5, "B[{i},{kk}]");
            }
        }
    }

    #[test]
    fn ridge_matches_normal_equations() {
        // B should minimize ||H - H_P Bᵀ||² + λ||B||²; check against an
        // explicit least-squares residual-orthogonality test.
        let mut r = Pcg64::seed(10);
        let n = 200;
        let h = 8;
        let p = [0usize, 2, 3, 7];
        let x = randn(&mut r, &[n, h]);
        let xp = crate::tensor::ops::gather_cols(&x, &p);
        let g = gram(&x);
        let g_ph = crate::tensor::ops::gather_rows(&g, &p);
        let g_pp = crate::tensor::ops::gather_cols(&g_ph, &p);
        let lambda = 1e-3 * mean_diag(&g_pp);
        let b = ridge_reconstruction(&g_pp, &g_ph, lambda);
        // Gradient of the objective wrt B must vanish:
        //   -2 H_Pᵀ(H - H_P Bᵀ) + 2λBᵀ = 0  ⇔  G_PP Bᵀ + λBᵀ = G_PH.
        let bt = transpose(&b);
        let mut lhs = matmul(&g_pp, &bt);
        crate::tensor::ops::axpy(&mut lhs, lambda, &bt);
        assert!(lhs.max_abs_diff(&g_ph) < 1e-2 * (n as f32).sqrt());
        // And reconstruction error should be far below predicting zero.
        let rec = matmul(&xp, &bt);
        let err = rec.max_abs_diff(&x);
        assert!(err.is_finite());
        let base: f32 = x.frobenius();
        let diff = {
            let mut d = rec.clone();
            crate::tensor::ops::axpy(&mut d, -1.0, &x);
            d.frobenius()
        };
        assert!(diff < base, "reconstruction no better than zero");
    }
}
