//! PCG-XSH-RR 64/32-based generator producing u64s (two 32-bit outputs
//! per draw). Reference: O'Neill, "PCG: A Family of Simple Fast
//! Space-Efficient Statistically Good Algorithms for Random Number
//! Generation" (2014).

const MUL: u64 = 6364136223846793005;
const INC: u64 = 1442695040888963407;

/// Deterministic PCG generator. `Clone` gives an identical stream copy.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u64,
    inc: u64,
    pub(crate) spare: Option<f32>,
}

impl Pcg64 {
    /// Seed a generator. Equal seeds yield equal streams.
    pub fn seed(seed: u64) -> Self {
        let mut rng = Pcg64 { state: 0, inc: INC | 1, spare: None };
        rng.state = rng.state.wrapping_mul(MUL).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(seed);
        rng.state = rng.state.wrapping_mul(MUL).wrapping_add(rng.inc);
        rng
    }

    /// Seed with an independent stream id, so `(seed, stream)` pairs are
    /// decorrelated (used to hand one generator per worker thread).
    pub fn seed_stream(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg64 {
            state: 0,
            inc: (stream.wrapping_mul(2).wrapping_add(1)) ^ INC,
            spare: None,
        };
        rng.inc |= 1;
        rng.state = rng.state.wrapping_mul(MUL).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(seed);
        rng.state = rng.state.wrapping_mul(MUL).wrapping_add(rng.inc);
        rng
    }

    #[inline]
    fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(MUL).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let hi = self.next_u32() as u64;
        let lo = self.next_u32() as u64;
        (hi << 32) | lo
    }

    /// Derive a child generator (for reproducible fan-out).
    pub fn fork(&mut self, tag: u64) -> Pcg64 {
        Pcg64::seed_stream(self.next_u64() ^ tag, tag.wrapping_add(0x9E3779B97F4A7C15))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn streams_are_decorrelated() {
        let mut a = Pcg64::seed_stream(42, 0);
        let mut b = Pcg64::seed_stream(42, 1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn fork_is_deterministic() {
        let mut a = Pcg64::seed(1);
        let mut b = Pcg64::seed(1);
        let mut fa = a.fork(3);
        let mut fb = b.fork(3);
        for _ in 0..16 {
            assert_eq!(fa.next_u64(), fb.next_u64());
        }
    }
}
