//! Deterministic pseudo-random number generation.
//!
//! The offline build environment has no `rand` crate, so this module
//! provides a small, fully deterministic PCG-based generator plus the
//! distributions the rest of the library needs (uniform, normal,
//! categorical, permutations). Every experiment seeds its generators
//! explicitly so all results are reproducible bit-for-bit.

mod pcg;

pub use pcg::Pcg64;

/// Convenience alias: the library-wide default generator.
pub type Rng = Pcg64;

impl Pcg64 {
    /// Uniform f32 in `[0, 1)`.
    pub fn next_f32(&mut self) -> f32 {
        // 24 mantissa bits of a u64 draw.
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[lo, hi)`.
    pub fn uniform(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f32()
    }

    /// Uniform integer in `[0, n)` via Lemire's method (unbiased enough
    /// for our n ≪ 2^64 use cases).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0) is undefined");
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal draw (Box–Muller; one value per call, the spare
    /// is cached).
    pub fn normal(&mut self) -> f32 {
        if let Some(z) = self.spare.take() {
            return z;
        }
        // Rejection-free polar-less Box-Muller.
        let mut u1 = self.next_f64();
        if u1 < 1e-300 {
            u1 = 1e-300;
        }
        let u2 = self.next_f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare = Some((r * theta.sin()) as f32);
        (r * theta.cos()) as f32
    }

    /// Normal with explicit mean / stddev.
    pub fn normal_ms(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal()
    }

    /// Fill a slice with iid standard normals scaled by `std`.
    pub fn fill_normal(&mut self, buf: &mut [f32], std: f32) {
        for v in buf.iter_mut() {
            *v = self.normal() * std;
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f32]) -> usize {
        let total: f32 = weights.iter().sum();
        assert!(total > 0.0, "categorical needs positive total mass");
        let mut t = self.next_f32() * total;
        for (i, &w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// A random permutation of `0..n`.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }

    /// `k` distinct indices sampled uniformly from `0..n` (k ≤ n).
    pub fn choose_k(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "choose_k: k={k} > n={n}");
        let mut p = self.permutation(n);
        p.truncate(k);
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg64::seed(42);
        let mut b = Pcg64::seed(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::seed(1);
        let mut b = Pcg64::seed(2);
        let same = (0..32).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_mean_is_half() {
        let mut r = Pcg64::seed(7);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.next_f32() as f64).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::seed(9);
        let n = 40_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal() as f64).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Pcg64::seed(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let i = r.below(10);
            assert!(i < 10);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Pcg64::seed(11);
        let w = [0.0, 1.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..8000 {
            counts[r.categorical(&w)] += 1;
        }
        assert_eq!(counts[0], 0);
        let ratio = counts[2] as f64 / counts[1] as f64;
        assert!((ratio - 3.0).abs() < 0.4, "ratio={ratio}");
    }

    #[test]
    fn permutation_is_permutation() {
        let mut r = Pcg64::seed(5);
        let p = r.permutation(57);
        let mut sorted = p.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..57).collect::<Vec<_>>());
    }

    #[test]
    fn choose_k_distinct() {
        let mut r = Pcg64::seed(6);
        let ks = r.choose_k(20, 8);
        assert_eq!(ks.len(), 8);
        let mut s = ks.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 8);
        assert!(s.iter().all(|&i| i < 20));
    }
}
