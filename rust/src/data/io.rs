//! Binary interchange formats between the Rust coordinator and the
//! build-time Python layer. All little-endian, versioned by magic.
//!
//! - `GRTK` token streams (`*.tokens`): u32 magic, u32 vocab, u64 len,
//!   u16 tokens.
//! - `GRIM` image sets (`*.imgs`): u32 magic, u32 n/c/h/w, f32 images
//!   (n·c·h·w, CHW), u16 labels (n).
//! - `GRWB` weight bundles (`*.wbin`): u32 magic, u32 version, u32
//!   count, then per tensor: u32 name_len, name bytes, u32 ndim, u32
//!   dims…, f32 data. (Readers/writers for this live in
//!   [`crate::nn::weights`].)

use super::{TokenSet, VisionSet};
use crate::tensor::Tensor;
use anyhow::{bail, Context, Result};
use std::io::{Read, Write};

pub const MAGIC_TOKENS: u32 = 0x4752_544B; // "GRTK"
pub const MAGIC_IMAGES: u32 = 0x4752_494D; // "GRIM"

fn w_u32(out: &mut impl Write, v: u32) -> Result<()> {
    out.write_all(&v.to_le_bytes())?;
    Ok(())
}

fn w_u64(out: &mut impl Write, v: u64) -> Result<()> {
    out.write_all(&v.to_le_bytes())?;
    Ok(())
}

fn r_u32(inp: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    inp.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn r_u64(inp: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    inp.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

/// Write a token stream.
pub fn write_tokens(path: &str, t: &TokenSet) -> Result<()> {
    let mut f = std::io::BufWriter::new(
        std::fs::File::create(path).with_context(|| format!("creating {path}"))?,
    );
    w_u32(&mut f, MAGIC_TOKENS)?;
    w_u32(&mut f, t.vocab as u32)?;
    w_u64(&mut f, t.tokens.len() as u64)?;
    let mut buf = Vec::with_capacity(t.tokens.len() * 2);
    for &tok in &t.tokens {
        buf.extend_from_slice(&tok.to_le_bytes());
    }
    f.write_all(&buf)?;
    Ok(())
}

/// Read a token stream.
pub fn read_tokens(path: &str) -> Result<TokenSet> {
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path).with_context(|| format!("opening {path}"))?,
    );
    if r_u32(&mut f)? != MAGIC_TOKENS {
        bail!("{path}: not a GRTK token file");
    }
    let vocab = r_u32(&mut f)? as usize;
    let len = r_u64(&mut f)? as usize;
    let mut buf = vec![0u8; len * 2];
    f.read_exact(&mut buf).with_context(|| format!("{path}: truncated token data"))?;
    let tokens: Vec<u16> =
        buf.chunks_exact(2).map(|c| u16::from_le_bytes([c[0], c[1]])).collect();
    for &t in &tokens {
        if t as usize >= vocab {
            bail!("{path}: token {t} out of vocab {vocab}");
        }
    }
    Ok(TokenSet { tokens, vocab })
}

/// Write an image set.
pub fn write_images(path: &str, v: &VisionSet) -> Result<()> {
    let mut f = std::io::BufWriter::new(
        std::fs::File::create(path).with_context(|| format!("creating {path}"))?,
    );
    let (c, h, w) = v.chw;
    w_u32(&mut f, MAGIC_IMAGES)?;
    w_u32(&mut f, v.len() as u32)?;
    w_u32(&mut f, c as u32)?;
    w_u32(&mut f, h as u32)?;
    w_u32(&mut f, w as u32)?;
    let mut buf = Vec::with_capacity(v.x.len() * 4);
    for &val in v.x.data() {
        buf.extend_from_slice(&val.to_le_bytes());
    }
    for &y in &v.y {
        buf.extend_from_slice(&y.to_le_bytes());
    }
    f.write_all(&buf)?;
    Ok(())
}

/// Read an image set.
pub fn read_images(path: &str) -> Result<VisionSet> {
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path).with_context(|| format!("opening {path}"))?,
    );
    if r_u32(&mut f)? != MAGIC_IMAGES {
        bail!("{path}: not a GRIM image file");
    }
    let n = r_u32(&mut f)? as usize;
    let c = r_u32(&mut f)? as usize;
    let h = r_u32(&mut f)? as usize;
    let w = r_u32(&mut f)? as usize;
    let d = c * h * w;
    let mut buf = vec![0u8; n * d * 4];
    f.read_exact(&mut buf).with_context(|| format!("{path}: truncated image data"))?;
    let x: Vec<f32> = buf
        .chunks_exact(4)
        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect();
    let mut lbuf = vec![0u8; n * 2];
    f.read_exact(&mut lbuf).with_context(|| format!("{path}: truncated labels"))?;
    let y: Vec<u16> = lbuf.chunks_exact(2).map(|b| u16::from_le_bytes([b[0], b[1]])).collect();
    Ok(VisionSet { x: Tensor::from_vec(&[n, d], x), y, chw: (c, h, w) })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{SynthText, SynthVision, TextSplit};

    fn tmp(name: &str) -> String {
        let dir = std::env::temp_dir().join("grail_io_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name).to_string_lossy().into_owned()
    }

    #[test]
    fn tokens_roundtrip() {
        let t = SynthText::new(1).generate(TextSplit::C4s, 777);
        let p = tmp("t.tokens");
        write_tokens(&p, &t).unwrap();
        let r = read_tokens(&p).unwrap();
        assert_eq!(r.tokens, t.tokens);
        assert_eq!(r.vocab, t.vocab);
    }

    #[test]
    fn images_roundtrip() {
        let v = SynthVision::new(2).generate(13);
        let p = tmp("v.imgs");
        write_images(&p, &v).unwrap();
        let r = read_images(&p).unwrap();
        assert_eq!(r.x, v.x);
        assert_eq!(r.y, v.y);
        assert_eq!(r.chw, v.chw);
    }

    #[test]
    fn wrong_magic_rejected() {
        let p = tmp("bad.bin");
        std::fs::write(&p, b"XXXXYYYYZZZZ").unwrap();
        assert!(read_tokens(&p).is_err());
        assert!(read_images(&p).is_err());
    }

    #[test]
    fn truncated_rejected() {
        let t = SynthText::new(1).generate(TextSplit::C4s, 100);
        let p = tmp("trunc.tokens");
        write_tokens(&p, &t).unwrap();
        let data = std::fs::read(&p).unwrap();
        std::fs::write(&p, &data[..data.len() / 2]).unwrap();
        assert!(read_tokens(&p).is_err());
    }
}
