//! SynthVision: a procedural 10-class image distribution.
//!
//! Stands in for CIFAR-10/ImageNet (unavailable offline; DESIGN.md §2).
//! Each class has a fixed prototype built from a few random sinusoidal
//! gratings plus a class-specific colour cast; samples are amplitude-
//! jittered, circularly shifted, and noised. The resulting images have
//! strong cross-channel correlations, which is exactly the regime where
//! GRAIL's second-order compensation matters.

use super::VisionSet;
use crate::rng::Pcg64;
use crate::tensor::Tensor;

/// Default image geometry.
pub const CHANNELS: usize = 3;
pub const HEIGHT: usize = 16;
pub const WIDTH: usize = 16;
/// Number of classes.
pub const CLASSES: usize = 10;

/// Deterministic generator for the SynthVision distribution.
pub struct SynthVision {
    seed: u64,
    prototypes: Vec<Vec<f32>>, // CLASSES × (C*H*W)
}

/// A mini-batch of images (flattened CHW) and labels.
pub struct VisionBatch {
    pub x: Tensor,
    pub y: Vec<u16>,
}

impl SynthVision {
    /// Build the class prototypes for a seed.
    pub fn new(seed: u64) -> Self {
        let mut rng = Pcg64::seed_stream(seed, 0x5EED_0001);
        let mut prototypes = Vec::with_capacity(CLASSES);
        for _class in 0..CLASSES {
            let mut proto = vec![0.0f32; CHANNELS * HEIGHT * WIDTH];
            // 3 random gratings shared across channels with per-channel
            // gains -> correlated channels.
            let gratings: Vec<(f32, f32, f32, f32)> = (0..3)
                .map(|_| {
                    (
                        rng.uniform(0.5, 3.0),  // fx
                        rng.uniform(0.5, 3.0),  // fy
                        rng.uniform(0.0, std::f32::consts::TAU), // phase
                        rng.uniform(0.4, 1.0),  // amplitude
                    )
                })
                .collect();
            let gains: Vec<f32> = (0..CHANNELS * 3).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let cast: Vec<f32> = (0..CHANNELS).map(|_| rng.uniform(-0.5, 0.5)).collect();
            for c in 0..CHANNELS {
                for yy in 0..HEIGHT {
                    for xx in 0..WIDTH {
                        let mut v = cast[c];
                        for (gi, &(fx, fy, ph, amp)) in gratings.iter().enumerate() {
                            let arg = std::f32::consts::TAU
                                * (fx * xx as f32 / WIDTH as f32 + fy * yy as f32 / HEIGHT as f32)
                                + ph;
                            v += gains[c * 3 + gi] * amp * arg.sin();
                        }
                        proto[c * HEIGHT * WIDTH + yy * WIDTH + xx] = v;
                    }
                }
            }
            prototypes.push(proto);
        }
        SynthVision { seed, prototypes }
    }

    /// Render one sample of class `class` using `rng` for jitter.
    fn sample(&self, class: usize, rng: &mut Pcg64, out: &mut [f32]) {
        let amp = rng.uniform(0.6, 1.4);
        let dx = rng.below(9) as isize - 4;
        let dy = rng.below(9) as isize - 4;
        let noise = 1.4f32;
        let proto = &self.prototypes[class];
        for c in 0..CHANNELS {
            for yy in 0..HEIGHT {
                for xx in 0..WIDTH {
                    let sy = (yy as isize + dy).rem_euclid(HEIGHT as isize) as usize;
                    let sx = (xx as isize + dx).rem_euclid(WIDTH as isize) as usize;
                    let base = proto[c * HEIGHT * WIDTH + sy * WIDTH + sx];
                    out[c * HEIGHT * WIDTH + yy * WIDTH + xx] =
                        amp * base + noise * rng.normal();
                }
            }
        }
    }

    /// Generate `n` samples with balanced classes (deterministic for a
    /// given generator seed and `n`).
    pub fn generate(&self, n: usize) -> VisionSet {
        self.generate_split(n, 0)
    }

    /// Generate a disjoint split: same class prototypes (same task),
    /// different sample stream — train/test/calibration splits share
    /// the distribution but not the samples.
    pub fn generate_split(&self, n: usize, split: u64) -> VisionSet {
        let d = CHANNELS * HEIGHT * WIDTH;
        let mut x = Tensor::zeros(&[n, d]);
        let mut y = Vec::with_capacity(n);
        let mut rng = Pcg64::seed_stream(self.seed, 0xDA7A ^ (split << 32));
        for i in 0..n {
            let class = i % CLASSES;
            self.sample(class, &mut rng, x.row_mut(i));
            y.push(class as u16);
        }
        // Deterministic shuffle so batches are class-mixed.
        let perm = Pcg64::seed_stream(self.seed, 0x5EED_0002 ^ split).permutation(n);
        let mut xs = Tensor::zeros(&[n, d]);
        let mut ys = vec![0u16; n];
        for (dst, &src) in perm.iter().enumerate() {
            xs.row_mut(dst).copy_from_slice(x.row(src));
            ys[dst] = y[src];
        }
        VisionSet { x: xs, y: ys, chw: (CHANNELS, HEIGHT, WIDTH) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let a = SynthVision::new(1).generate(40);
        let b = SynthVision::new(1).generate(40);
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
    }

    #[test]
    fn classes_balanced_and_in_range() {
        let s = SynthVision::new(2).generate(100);
        let mut counts = [0usize; CLASSES];
        for &c in &s.y {
            counts[c as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c == 10), "{counts:?}");
    }

    #[test]
    fn classes_are_separable_by_prototype_distance() {
        // Nearest-prototype classification on clean stats should beat
        // chance by a wide margin — sanity that the task is learnable.
        let g = SynthVision::new(3);
        let s = g.generate(200);
        let d = s.x.dim(1);
        let mut correct = 0;
        for i in 0..s.len() {
            let xi = s.x.row(i);
            let (mut best, mut bd) = (0usize, f64::INFINITY);
            for (c, p) in g.prototypes.iter().enumerate() {
                let dist: f64 = xi
                    .iter()
                    .zip(p)
                    .map(|(a, b)| ((a - b) as f64).powi(2))
                    .sum();
                if dist < bd {
                    bd = dist;
                    best = c;
                }
            }
            if best == s.y[i] as usize {
                correct += 1;
            }
            let _ = d;
        }
        // Noise is deliberately high (trained nets reach ~90-98%, a
        // naive nearest-prototype rule much less) — just demand a wide
        // margin over the 10% chance level.
        assert!(correct > 60, "nearest-prototype acc only {correct}/200");
    }

    #[test]
    fn different_seeds_give_different_tasks() {
        let a = SynthVision::new(10).generate(10);
        let b = SynthVision::new(11).generate(10);
        assert!(a.x.max_abs_diff(&b.x) > 0.1);
    }
}
