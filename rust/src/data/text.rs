//! SynthText: a seeded stochastic-grammar corpus.
//!
//! Stands in for C4 / WikiText-2 / PTB (DESIGN.md §2). Token streams
//! are sampled from a first-order Markov chain whose transition logits
//! are drawn from a shared base (so a model trained on the train split
//! is meaningfully evaluated on all three eval splits) plus a per-split
//! perturbation and temperature — giving the three splits different
//! entropies, like the paper's three corpora.

use super::TokenSet;
use crate::rng::Pcg64;

/// Vocabulary size of the synthetic language.
pub const VOCAB: usize = 64;

/// The three evaluation splits (named after the corpora they replace)
/// plus the train/calibration splits.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TextSplit {
    Train,
    Calib,
    /// C4 stand-in: same statistics as train.
    C4s,
    /// WikiText-2 stand-in: mild perturbation, colder.
    Wt2s,
    /// PTB stand-in: stronger perturbation, hotter.
    Ptbs,
}

impl TextSplit {
    /// All splits `grail datagen` materializes.
    pub const ALL: [TextSplit; 5] =
        [TextSplit::Train, TextSplit::Calib, TextSplit::C4s, TextSplit::Wt2s, TextSplit::Ptbs];

    /// Stable file-name stem.
    pub fn name(&self) -> &'static str {
        match self {
            TextSplit::Train => "train",
            TextSplit::Calib => "calib",
            TextSplit::C4s => "c4s",
            TextSplit::Wt2s => "wt2s",
            TextSplit::Ptbs => "ptbs",
        }
    }

    /// Parse a stem back into a split.
    pub fn from_name(s: &str) -> Option<TextSplit> {
        Self::ALL.iter().copied().find(|t| t.name() == s)
    }

    /// (perturbation strength, inverse temperature, stream tag).
    fn params(&self) -> (f32, f32, u64) {
        match self {
            TextSplit::Train => (0.0, 1.0, 1),
            TextSplit::Calib => (0.0, 1.0, 2),
            TextSplit::C4s => (0.0, 1.0, 3),
            TextSplit::Wt2s => (0.15, 1.15, 4),
            TextSplit::Ptbs => (0.35, 0.9, 5),
        }
    }
}

/// Deterministic generator for the SynthText language.
pub struct SynthText {
    seed: u64,
    base_logits: Vec<f32>, // VOCAB × VOCAB
}

impl SynthText {
    /// Build the shared base transition logits for a seed.
    pub fn new(seed: u64) -> Self {
        let mut rng = Pcg64::seed_stream(seed, 0x7E27_0001);
        let mut base_logits = vec![0.0f32; VOCAB * VOCAB];
        for row in 0..VOCAB {
            // Sparse-ish structure: a handful of preferred successors
            // per token makes the language genuinely learnable.
            let strong: Vec<usize> = (0..4).map(|_| rng.below(VOCAB)).collect();
            for col in 0..VOCAB {
                let mut l = rng.normal() * 0.4;
                if strong.contains(&col) {
                    l += 4.0;
                }
                base_logits[row * VOCAB + col] = l;
            }
        }
        SynthText { seed, base_logits }
    }

    /// Transition probabilities for a split (row-stochastic
    /// `VOCAB×VOCAB`).
    pub fn transition(&self, split: TextSplit) -> Vec<f32> {
        let (eps, beta, tag) = split.params();
        let mut rng = Pcg64::seed_stream(self.seed, 0x7E27_0100 + tag);
        let mut probs = vec![0.0f32; VOCAB * VOCAB];
        for row in 0..VOCAB {
            let mut mx = f32::NEG_INFINITY;
            let mut logits = [0.0f32; VOCAB];
            for col in 0..VOCAB {
                let l = beta * (self.base_logits[row * VOCAB + col] + eps * rng.normal());
                logits[col] = l;
                mx = mx.max(l);
            }
            let mut z = 0.0f32;
            for col in 0..VOCAB {
                let e = (logits[col] - mx).exp();
                probs[row * VOCAB + col] = e;
                z += e;
            }
            for col in 0..VOCAB {
                probs[row * VOCAB + col] /= z;
            }
        }
        probs
    }

    /// Sample a token stream of length `n` for a split.
    pub fn generate(&self, split: TextSplit, n: usize) -> TokenSet {
        let probs = self.transition(split);
        let (_, _, tag) = split.params();
        let mut rng = Pcg64::seed_stream(self.seed, 0x7E27_0200 + tag);
        let mut tokens = Vec::with_capacity(n);
        let mut cur = rng.below(VOCAB);
        for _ in 0..n {
            tokens.push(cur as u16);
            let row = &probs[cur * VOCAB..(cur + 1) * VOCAB];
            cur = rng.categorical(row);
        }
        TokenSet { tokens, vocab: VOCAB }
    }

    /// True per-token cross-entropy (nats) of split `b` under the
    /// transition model of split `a` — an oracle lower bound for model
    /// perplexity, used by tests.
    pub fn cross_entropy(&self, model_of: TextSplit, data_from: TextSplit, n: usize) -> f64 {
        let p_model = self.transition(model_of);
        let data = self.generate(data_from, n);
        let mut nll = 0.0f64;
        let mut count = 0usize;
        for w in data.tokens.windows(2) {
            let (a, b) = (w[0] as usize, w[1] as usize);
            let p = p_model[a * VOCAB + b].max(1e-12);
            nll -= (p as f64).ln();
            count += 1;
        }
        nll / count.max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let a = SynthText::new(1).generate(TextSplit::Train, 500);
        let b = SynthText::new(1).generate(TextSplit::Train, 500);
        assert_eq!(a.tokens, b.tokens);
    }

    #[test]
    fn splits_differ_but_share_structure() {
        let g = SynthText::new(2);
        // Oracle CE of each split under its own model is below log(V)
        // (the language is compressible) ...
        let h_self = g.cross_entropy(TextSplit::C4s, TextSplit::C4s, 20_000);
        assert!(h_self < (VOCAB as f64).ln() * 0.8, "h_self={h_self}");
        // ... and the train model transfers to the perturbed splits
        // better than a uniform model, but pays a transfer penalty
        // relative to each split's own oracle.
        for s in [TextSplit::Wt2s, TextSplit::Ptbs] {
            let h = g.cross_entropy(TextSplit::Train, s, 20_000);
            assert!(h < (VOCAB as f64).ln(), "{s:?}: {h}");
            let oracle = g.cross_entropy(s, s, 20_000);
            assert!(h >= oracle - 1e-9, "{s:?}: transfer {h} below oracle {oracle}");
        }
    }

    #[test]
    fn transition_rows_stochastic() {
        let g = SynthText::new(3);
        let p = g.transition(TextSplit::Ptbs);
        for row in 0..VOCAB {
            let s: f32 = p[row * VOCAB..(row + 1) * VOCAB].iter().sum();
            assert!((s - 1.0).abs() < 1e-4, "row {row} sums to {s}");
            assert!(p[row * VOCAB..(row + 1) * VOCAB].iter().all(|&v| v >= 0.0));
        }
    }

    #[test]
    fn tokens_in_vocab() {
        let t = SynthText::new(4).generate(TextSplit::Wt2s, 1000);
        assert!(t.tokens.iter().all(|&v| (v as usize) < VOCAB));
        assert_eq!(t.vocab, VOCAB);
    }

    #[test]
    fn split_roundtrip_names() {
        for s in TextSplit::ALL {
            assert_eq!(TextSplit::from_name(s.name()), Some(s));
        }
        assert_eq!(TextSplit::from_name("bogus"), None);
    }
}
