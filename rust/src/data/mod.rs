//! Synthetic datasets (the repro substitutes for CIFAR-10/ImageNet and
//! C4/WikiText-2/PTB — see DESIGN.md §2).
//!
//! Rust is the *single source of truth* for data: `grail datagen`
//! writes the corpora under `artifacts/data/`, the build-time Python
//! training step reads the same binary files, and all experiments load
//! them back here. This avoids any cross-language generator drift.

pub mod io;
pub mod text;
pub mod vision;

pub use text::{SynthText, TextSplit};
pub use vision::{SynthVision, VisionBatch};

use crate::tensor::Tensor;

/// A labelled vision dataset held in memory.
#[derive(Clone)]
pub struct VisionSet {
    /// Images, `[n, c*h*w]` flattened CHW.
    pub x: Tensor,
    /// Class labels.
    pub y: Vec<u16>,
    /// Channel/height/width.
    pub chw: (usize, usize, usize),
}

impl VisionSet {
    /// Number of samples.
    pub fn len(&self) -> usize {
        self.y.len()
    }

    /// True if empty.
    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    /// A contiguous sub-range as a batch view (copies).
    pub fn slice(&self, start: usize, n: usize) -> VisionSet {
        let d = self.x.dim(1);
        let end = (start + n).min(self.len());
        let xs = self.x.data()[start * d..end * d].to_vec();
        VisionSet {
            x: Tensor::from_vec(&[end - start, d], xs),
            y: self.y[start..end].to_vec(),
            chw: self.chw,
        }
    }
}

/// A token stream plus its vocabulary size.
#[derive(Clone)]
pub struct TokenSet {
    pub tokens: Vec<u16>,
    pub vocab: usize,
}

impl TokenSet {
    /// Cut the stream into `[B, T+1]` next-token prediction windows
    /// (inputs are `[.., :T]`, targets `[.., 1:]`). Returns row-major
    /// token ids.
    pub fn windows(&self, seq_len: usize, max_windows: usize) -> Vec<Vec<u16>> {
        let mut out = Vec::new();
        let mut i = 0;
        while i + seq_len + 1 <= self.tokens.len() && out.len() < max_windows {
            out.push(self.tokens[i..i + seq_len + 1].to_vec());
            i += seq_len;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vision_slice_bounds() {
        let v = vision::SynthVision::new(3).generate(10);
        let s = v.slice(7, 5);
        assert_eq!(s.len(), 3); // clamped to the end
        assert_eq!(s.x.dim(0), 3);
    }

    #[test]
    fn token_windows_shapes() {
        let ts = TokenSet { tokens: (0..100u16).map(|i| i % 7).collect(), vocab: 7 };
        let w = ts.windows(16, 100);
        assert!(!w.is_empty());
        for win in &w {
            assert_eq!(win.len(), 17);
        }
        // Consecutive windows overlap by exactly one token.
        assert_eq!(w[0][16], w[1][0]);
    }
}
