//! Offline stub of the `xla` crate's PJRT surface.
//!
//! Mirrors the exact API `grail::runtime` uses so the `pjrt` feature
//! compiles without a system XLA/PJRT install. Every entry point fails
//! at runtime with a clear "PJRT unavailable" error, which the runtime
//! and its tests already treat as a skip condition. To execute the AOT
//! HLO artifacts for real, point the `xla` path dependency in the
//! workspace `Cargo.toml` at the actual bindings.

use std::fmt;

/// Stub error: always "PJRT unavailable".
#[derive(Debug, Clone)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

/// Stub result alias matching the real crate.
pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "PJRT unavailable: {what} (offline xla stub; link the real `xla` crate to run HLO artifacts)"
    )))
}

/// PJRT client handle (stub).
pub struct PjRtClient;

impl PjRtClient {
    /// Always errors: no PJRT runtime is linked in.
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    /// Platform string.
    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    /// Compile a computation (unreachable: no client can be built).
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

/// Parsed HLO module (stub).
pub struct HloModuleProto;

impl HloModuleProto {
    /// Always errors: HLO text parsing needs the real bindings.
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// XLA computation (stub).
pub struct XlaComputation;

impl XlaComputation {
    /// Wrap a parsed proto.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Array shape of a literal (stub).
pub struct ArrayShape;

impl ArrayShape {
    /// Dimensions.
    pub fn dims(&self) -> &[i64] {
        &[]
    }
}

/// Host literal (stub).
pub struct Literal;

impl Literal {
    /// Build a rank-1 literal from a slice.
    pub fn vec1<T: Copy>(_data: &[T]) -> Literal {
        Literal
    }

    /// Reshape to the given dimensions.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable("Literal::reshape")
    }

    /// Unpack a tuple literal.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }

    /// Shape accessor.
    pub fn array_shape(&self) -> Result<ArrayShape> {
        unavailable("Literal::array_shape")
    }

    /// Copy out as a typed vector.
    pub fn to_vec<T: Copy>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }
}

/// Device buffer (stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Copy back to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Compiled executable (stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Execute on literal inputs.
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn everything_reports_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        assert!(Literal::vec1(&[1.0f32]).reshape(&[1]).is_err());
        let err = PjRtClient::cpu().unwrap_err().to_string();
        assert!(err.contains("PJRT unavailable"));
    }
}
