//! Minimal, offline stand-in for the `anyhow` crate.
//!
//! Implements the API subset this workspace uses — `Error`, `Result`,
//! `Context::{context, with_context}`, and the `anyhow!` / `bail!` /
//! `ensure!` macros — with no dependencies, so the build never needs a
//! crates.io registry. Error values keep a flattened context chain:
//! `Display` shows the outermost message, `{:#}` joins the chain with
//! `": "` (matching real anyhow), and `Debug` renders a "Caused by"
//! list.

use std::fmt;

/// A context-carrying error value. Like `anyhow::Error`, this type
/// deliberately does NOT implement `std::error::Error`, which is what
/// keeps the blanket `From<E: std::error::Error>` impl coherent.
pub struct Error {
    /// Outermost context first.
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { chain: vec![message.to_string()] }
    }

    /// Prepend a context layer.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The source chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The outermost message.
    pub fn root_message(&self) -> &str {
        &self.chain[0]
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, c) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {c}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to errors (the `anyhow::Context` extension trait).
pub trait Context<T, E> {
    /// Wrap the error value with additional context.
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static;

    /// Wrap the error value with lazily evaluated context.
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T, E> for Result<T, E>
where
    E: Into<Error>,
{
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(context)
        })
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(f())
        })
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::core::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!(concat!("condition failed: `", stringify!($cond), "`"));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_and_alternate() {
        let e: Error = Error::msg("inner").context("outer");
        assert_eq!(e.to_string(), "outer");
        assert_eq!(format!("{e:#}"), "outer: inner");
        assert!(format!("{e:?}").contains("Caused by"));
    }

    #[test]
    fn context_on_std_results() {
        let r: Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading file").unwrap_err();
        assert_eq!(e.to_string(), "reading file");
        assert_eq!(format!("{e:#}"), "reading file: gone");
    }

    #[test]
    fn with_context_on_anyhow_results() {
        let r: Result<()> = Err(anyhow!("base {}", 7));
        let e = r.with_context(|| format!("step {}", 1)).unwrap_err();
        assert_eq!(format!("{e:#}"), "step 1: base 7");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        assert_eq!(v.context("missing").unwrap_err().to_string(), "missing");
        assert_eq!(Some(3u32).context("missing").unwrap(), 3);
    }

    #[test]
    fn question_mark_converts() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(inner().unwrap_err().to_string(), "gone");
    }

    #[test]
    fn bail_and_ensure() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative: {x}");
            if x > 100 {
                bail!("too big: {x}");
            }
            Ok(x)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert_eq!(f(-1).unwrap_err().to_string(), "negative: -1");
        assert_eq!(f(200).unwrap_err().to_string(), "too big: 200");
    }
}
