//! Serving-path benchmarks (custom harness — no criterion offline).
//!
//! Measures the inference surfaces this repo serves compressed models
//! through: fused GEMM epilogues vs unfused bias/activation sweeps,
//! prepacked vs per-call weight packing at decode row counts, batched
//! vs reference attention, KV-cache decode vs full-forward rescan
//! generation, a concurrent prefill+decode fleet (one thread per
//! request, the PR-6 path), and the continuous-batching scheduler —
//! closed-batch against the fleet baseline and under a deterministic
//! **open-loop** arrival process (arrivals are fixed in scheduler-step
//! units, never derived from the wall clock, so the workload replays
//! identically; the clock only timestamps it), plus a **long-prompt
//! chunked-prefill scenario** (one 8x-length prompt arriving
//! mid-stream) whose head-of-line gate is measured in deterministic
//! pass-row units. Every fast path is first asserted bit-identical to
//! (or token-identical with) its reference, then the speed claims are
//! *asserted* so CI fails on a serving regression. Results land
//! machine-readably in `BENCH_serve.json` (schema `grail-serve-v2` —
//! bumped from v1 when chunked prefill added the `prefill_*`,
//! `mixed_steps`, occupancy, stall, and `lm_head_rows_saved`
//! metrics); reproduction steps in EXPERIMENTS.md §Serving.

use std::time::Instant;

use grail::bench_util::{bench, pct, Recorder};
use grail::compress::Selector;
use grail::coordinator::scheduler::{default_threads, run_grid};
use grail::grail::{compress_model, CompressionSpec, Method};
use grail::nn::models::{LmBatch, LmConfig, TinyLm};
use grail::nn::{Activation, Linear, MultiHeadAttention};
use grail::rng::Pcg64;
use grail::serve::{BatchScheduler, BatchStats, DEFAULT_PREFILL_CHUNK};
use grail::tensor::gemm::Epilogue;
use grail::tensor::{ops, Tensor};

fn randn(rng: &mut Pcg64, shape: &[usize]) -> Tensor {
    let mut t = Tensor::zeros(shape);
    rng.fill_normal(t.data_mut(), 1.0);
    t
}

/// The pre-fusion linear forward: serve GEMM with no epilogue, then
/// separate bias and activation sweeps over the output.
fn linear_unfused(l: &Linear, x: &Tensor, act: Activation) -> Tensor {
    let (m, k, n) = (x.dim(0), l.in_dim(), l.out_dim());
    let mut y = Tensor::zeros(&[m, n]);
    ops::gemm_nt_serve(x.data(), l.w.data(), y.data_mut(), m, k, n, Epilogue::None);
    ops::add_bias(&mut y, l.b.data());
    match act {
        Activation::Identity => {}
        Activation::Relu => grail::nn::relu(&mut y),
        Activation::Gelu => grail::nn::gelu(&mut y),
    }
    y
}

fn assert_bits_eq(a: &Tensor, b: &Tensor, what: &str) {
    assert_eq!(a.shape(), b.shape(), "{what}: shape");
    for (x, y) in a.data().iter().zip(b.data()) {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: bits diverged");
    }
}

/// Deterministic prompt `len` tokens long, distinct per request id.
fn prompt(id: usize, len: usize) -> Vec<u16> {
    (0..len).map(|i| ((id * 13 + i * 7 + 3) % grail::data::text::VOCAB) as u16).collect()
}

/// Push `requests` prefill+decode generations through the scheduler and
/// return (requests/sec, sorted per-request latencies in ms).
fn serve_fleet(m: &TinyLm, requests: usize, p_len: usize, n_new: usize) -> (f64, Vec<f64>) {
    let prompts: Vec<Vec<u16>> = (0..requests).map(|i| prompt(i, p_len)).collect();
    let threads = default_threads().clamp(1, requests);
    let t0 = Instant::now();
    let mut lat = run_grid(prompts, threads, |_, p| {
        let t = Instant::now();
        std::hint::black_box(m.generate(p, n_new));
        t.elapsed().as_secs_f64() * 1e3
    });
    let wall = t0.elapsed().as_secs_f64();
    lat.sort_by(|a, b| a.total_cmp(b));
    (requests as f64 / wall, lat)
}

/// Exact worst-case page budget for `requests` concurrent generations
/// of `positions` total positions each, at page size `ps` — what the
/// continuous-batching scheduler's admission accounting reserves.
fn pool_pages_for(m: &TinyLm, requests: usize, positions: usize, ps: usize) -> usize {
    requests * 2 * m.cfg.n_layers * m.cfg.n_kv * ((positions + ps - 1) / ps)
}

/// Drive `requests` generations through the continuous-batching
/// scheduler. `arrive_every == 0` submits everything up front (closed
/// batch); `k > 0` admits one request every `k` scheduler steps — an
/// open-loop arrival process that is deterministic in step units (the
/// wall clock only timestamps the workload, never shapes it). `chunk`
/// is the per-step prefill row budget (`usize::MAX` reproduces the
/// unchunked one-shot-prefill schedule). Returns (requests/sec over
/// the whole run, sorted per-request latencies in ms, final scheduler
/// stats).
fn serve_batched(
    m: &TinyLm,
    requests: usize,
    p_len: usize,
    n_new: usize,
    arrive_every: usize,
    chunk: usize,
) -> (f64, Vec<f64>, BatchStats) {
    let ps = 8usize;
    let prompts: Vec<Vec<u16>> = (0..requests).map(|i| prompt(i, p_len)).collect();
    let pages = pool_pages_for(m, requests, p_len + n_new, ps);
    let mut sched = BatchScheduler::new(m, ps, pages, requests).with_prefill_chunk(chunk);
    let mut start_ms = vec![0.0f64; requests];
    let mut lat = vec![0.0f64; requests];
    let (mut submitted, mut completed, mut step_no) = (0usize, 0usize, 0usize);
    let t0 = Instant::now();
    while completed < requests {
        while submitted < requests && (arrive_every == 0 || step_no >= submitted * arrive_every) {
            let id = sched.submit(&prompts[submitted], n_new);
            start_ms[id] = t0.elapsed().as_secs_f64() * 1e3;
            submitted += 1;
        }
        for c in sched.step() {
            lat[c.id] = t0.elapsed().as_secs_f64() * 1e3 - start_ms[c.id];
            completed += 1;
        }
        step_no += 1;
    }
    let wall = t0.elapsed().as_secs_f64();
    let st = sched.stats();
    lat.sort_by(|a, b| a.total_cmp(b));
    (requests as f64 / wall, lat, st)
}

/// Mean coalesced decode rows per decode-bearing step — the PR-9
/// occupancy figure, kept for cross-schema comparability.
fn decode_occupancy(st: &BatchStats) -> f64 {
    st.coalesced_rows as f64 / st.decode_steps.max(1) as f64
}

const LONG_SHORTS: usize = 24;
const LONG_AT: usize = 12;
const LONG_SHORT_LEN: usize = 6;
const LONG_LONG_LEN: usize = 48;
const LONG_N_NEW: usize = 8;

/// The long-prompt head-of-line scenario: [`LONG_SHORTS`] short
/// requests arrive open-loop every 2 scheduler steps, with a single
/// 8x-length prompt injected mid-stream (arrival index [`LONG_AT`]).
/// The workload is deterministic in step units; the per-token *stall*
/// proxy for a decode token is the number of rows in the pass that
/// produced it (every row in a coalesced pass shares that pass's wall
/// time), also deterministic. Returns (per-request token streams,
/// sorted stall trace in pass rows, final stats, p99 per-step wall ms
/// — reported, never gated).
fn long_prompt_run(m: &TinyLm, chunk: usize) -> (Vec<Vec<u16>>, Vec<f64>, BatchStats, f64) {
    let total = LONG_SHORTS + 1;
    let prompts: Vec<Vec<u16>> = (0..total)
        .map(|i| {
            if i == LONG_AT { prompt(999, LONG_LONG_LEN) } else { prompt(i, LONG_SHORT_LEN) }
        })
        .collect();
    let ps = 8usize;
    let pages: usize =
        prompts.iter().map(|p| pool_pages_for(m, 1, p.len() + LONG_N_NEW, ps)).sum();
    let mut sched = BatchScheduler::new(m, ps, pages, 8).with_prefill_chunk(chunk);
    let mut streams: Vec<Vec<u16>> = vec![Vec::new(); total];
    let mut stalls: Vec<f64> = Vec::new();
    let mut step_ms: Vec<f64> = Vec::new();
    let (mut submitted, mut completed, mut step_no) = (0usize, 0usize, 0usize);
    while completed < total {
        while submitted < total && step_no >= submitted * 2 {
            let id = sched.submit(&prompts[submitted], LONG_N_NEW);
            assert_eq!(id, submitted, "scheduler ids must track submission order");
            submitted += 1;
        }
        let before = sched.stats();
        let t = Instant::now();
        let done = sched.step();
        step_ms.push(t.elapsed().as_secs_f64() * 1e3);
        let after = sched.stats();
        let rows = (after.pass_rows - before.pass_rows) as f64;
        // One stall sample per decode token emitted by this pass; a
        // token's stall is the whole pass's row count (every row in a
        // coalesced pass shares its wall time).
        for _ in 0..(after.coalesced_rows - before.coalesced_rows) {
            stalls.push(rows);
        }
        for c in done {
            streams[c.id] = c.tokens;
            completed += 1;
        }
        step_no += 1;
    }
    stalls.sort_by(|a, b| a.total_cmp(b));
    step_ms.sort_by(|a, b| a.total_cmp(b));
    let p99_ms = pct(&step_ms, 0.99);
    (streams, stalls, sched.stats(), p99_ms)
}

fn main() {
    let mut rng = Pcg64::seed(4242);
    let mut rec = Recorder::default();
    println!("== grail serving benchmarks ==\n");

    // --- Fused GEMM epilogue vs unfused bias/activation sweeps. The
    // shape is epilogue-bound on purpose (small k, wide n): the fused
    // path's win is exactly the two extra passes over C it removes.
    for (act, name, gate) in
        [(Activation::Relu, "relu", true), (Activation::Gelu, "gelu", false)]
    {
        let (m, k, n) = (512usize, 32usize, 1024usize);
        let l = Linear::init(n, k, &mut rng);
        let x = randn(&mut rng, &[m, k]);
        assert_bits_eq(
            &l.forward_act(&x, act),
            &linear_unfused(&l, &x, act),
            &format!("fused {name} epilogue vs unfused sweeps"),
        );
        let fused = bench(&format!("linear_fused {name} {m}x{k}x{n}"), 400, || {
            l.forward_act(&x, act)
        });
        let unfused = bench(&format!("linear_unfused {name} {m}x{k}x{n}"), 400, || {
            linear_unfused(&l, &x, act)
        });
        let speedup = unfused.median_ns / fused.median_ns;
        println!("{:<44} {:.2}x", format!("fused {name} epilogue speedup"), speedup);
        rec.push(&fused);
        rec.push(&unfused);
        rec.metric(&format!("fused_epilogue_speedup_{name}"), speedup);
        if gate {
            assert!(
                fused.median_ns < unfused.median_ns,
                "fused {name} epilogue must beat unfused sweeps ({speedup:.2}x)"
            );
        }
    }

    // --- Prepacked weights at decode row counts: a 1-row GEMM is
    // dominated by packing B, which prepack hoists out of the loop.
    {
        let (k, n) = (512usize, 512usize);
        let l = Linear::init(n, k, &mut rng);
        let pb = l.prepack();
        assert!(pb.is_some(), "512x512 layer must take the packed serving path");
        let x = randn(&mut rng, &[1, k]);
        assert_bits_eq(
            &l.forward_prepacked(pb.as_ref(), &x, Activation::Identity),
            &l.forward_act(&x, Activation::Identity),
            "prepacked vs per-call packing",
        );
        let pre = bench(&format!("linear_prepacked m=1 {k}x{n}"), 300, || {
            l.forward_prepacked(pb.as_ref(), &x, Activation::Identity)
        });
        let percall = bench(&format!("linear_percall   m=1 {k}x{n}"), 300, || {
            l.forward_act(&x, Activation::Identity)
        });
        let speedup = percall.median_ns / pre.median_ns;
        println!("{:<44} {:.2}x", "prepacked decode-GEMM speedup", speedup);
        rec.push(&pre);
        rec.push(&percall);
        rec.metric("prepack_speedup_m1", speedup);
        assert!(
            pre.median_ns < percall.median_ns,
            "prepacked weights must beat per-call packing at m=1 ({speedup:.2}x)"
        );
    }

    // --- Batched attention vs the serial per-head reference.
    {
        let attn = MultiHeadAttention::init(64, 8, 8, 8, true, &mut rng);
        let x = randn(&mut rng, &[16 * 32, 64]);
        let (y, tap) = attn.forward(&x, 16, 32);
        let (yr, tapr) = attn.forward_ref(&x, 16, 32);
        assert_bits_eq(&y, &yr, "batched attention output vs reference");
        assert_bits_eq(&tap, &tapr, "batched attention tap vs reference");
        let batched = bench("attention_batched b=16 t=32 h=8", 400, || attn.forward(&x, 16, 32));
        let reference = bench("attention_ref     b=16 t=32 h=8", 400, || {
            attn.forward_ref(&x, 16, 32)
        });
        let speedup = reference.median_ns / batched.median_ns;
        println!("{:<44} {:.2}x", "batched attention speedup", speedup);
        rec.push(&batched);
        rec.push(&reference);
        rec.metric("batched_attention_speedup", speedup);
        // On one worker the two paths do the same work modulo batching
        // overhead; the gate only forbids the fan-out *losing*.
        assert!(
            batched.median_ns < reference.median_ns * 1.10,
            "batched attention must not lose to the serial reference ({speedup:.2}x)"
        );
    }

    // --- KV-cache decode vs full-forward rescan generation, dense and
    // 50%-kept compressed TinyLm. Sequence length 8 + 56 = 64 (the
    // config's max_seq), where the rescan pays O(t) full forwards.
    let dense = TinyLm::init(LmConfig::default(), &mut rng);
    let compressed = {
        let mut m = dense.clone();
        let toks: Vec<u16> = (0..16 * 33).map(|i| (i % 64) as u16).collect();
        let ts = grail::data::TokenSet { tokens: toks, vocab: 64 };
        let calib = LmBatch::from_tokens(&ts, 32, 16);
        let spec = CompressionSpec::uniform(Method::Prune(Selector::Wanda), 0.5, true);
        let report = compress_model(&mut m, &calib, &spec);
        assert!(!report.sites.is_empty(), "compression must touch every site");
        m
    };
    let (p_len, n_new) = (8usize, 56usize);
    for (m, label) in [(&dense, "dense"), (&compressed, "compressed")] {
        let p = prompt(1, p_len);
        // Token-exact agreement between the KV-cache path and the
        // full-rescan oracle is the serving contract.
        assert_eq!(
            m.generate(&p, n_new),
            m.generate_rescan(&p, n_new),
            "{label}: decode and rescan generations must emit identical tokens"
        );
        let decode = bench(&format!("lm_generate_decode {label} p={p_len} new={n_new}"), 900, || {
            m.generate(&p, n_new)
        });
        let rescan = bench(&format!("lm_generate_rescan {label} p={p_len} new={n_new}"), 900, || {
            m.generate_rescan(&p, n_new)
        });
        let speedup = rescan.median_ns / decode.median_ns;
        println!("{:<44} {:.2}x", format!("kv-decode speedup ({label})"), speedup);
        rec.push(&decode);
        rec.push(&rescan);
        rec.metric(&format!("kv_decode_speedup_{label}"), speedup);
        assert!(
            speedup >= 2.0,
            "{label}: KV-cache decode must be >= 2x over rescan at seq 64, got {speedup:.2}x"
        );
    }

    // --- Worker-count invariance of the serving path: the same prompt
    // must generate the same tokens at any thread budget.
    {
        let p = prompt(2, p_len);
        let want = dense.generate(&p, n_new);
        for threads in ["1", "2", "4", "8"] {
            std::env::set_var("GRAIL_THREADS", threads);
            assert_eq!(
                dense.generate(&p, n_new),
                want,
                "generation must be identical at GRAIL_THREADS={threads}"
            );
        }
        std::env::remove_var("GRAIL_THREADS");
        println!("{:<44} ok", "worker-count invariance (1/2/4/8 threads)");
    }

    // --- Concurrent prefill+decode fleet: many requests fanned over
    // the scheduler's divided thread budget. The compressed model's
    // smaller GEMMs and K/V caches must buy real throughput.
    let (requests, fleet_new) = (32usize, 24usize);
    // Warm (page in caches, settle the pool), then measure twice
    // and keep the better run per model to damp scheduler noise.
    serve_fleet(&dense, requests, p_len, fleet_new);
    let (dense_rps, dense_lat) = {
        let a = serve_fleet(&dense, requests, p_len, fleet_new);
        let b = serve_fleet(&dense, requests, p_len, fleet_new);
        if a.0 >= b.0 { a } else { b }
    };
    serve_fleet(&compressed, requests, p_len, fleet_new);
    let (comp_rps, comp_lat) = {
        let a = serve_fleet(&compressed, requests, p_len, fleet_new);
        let b = serve_fleet(&compressed, requests, p_len, fleet_new);
        if a.0 >= b.0 { a } else { b }
    };
    println!(
        "{:<44} {dense_rps:.1} req/s  p50 {:.2} ms  p99 {:.2} ms",
        format!("fleet dense {requests} req"),
        pct(&dense_lat, 0.5),
        pct(&dense_lat, 0.99)
    );
    println!(
        "{:<44} {comp_rps:.1} req/s  p50 {:.2} ms  p99 {:.2} ms",
        format!("fleet compressed {requests} req"),
        pct(&comp_lat, 0.5),
        pct(&comp_lat, 0.99)
    );
    rec.metric("fleet_dense_rps", dense_rps);
    rec.metric("fleet_dense_p50_ms", pct(&dense_lat, 0.5));
    rec.metric("fleet_dense_p99_ms", pct(&dense_lat, 0.99));
    rec.metric("fleet_compressed_rps", comp_rps);
    rec.metric("fleet_compressed_p50_ms", pct(&comp_lat, 0.5));
    rec.metric("fleet_compressed_p99_ms", pct(&comp_lat, 0.99));
    rec.metric("fleet_compressed_rps_gain", comp_rps / dense_rps);
    assert!(
        comp_rps > dense_rps,
        "50%-kept compressed TinyLm must out-serve dense: {comp_rps:.1} vs {dense_rps:.1} req/s"
    );

    // --- Continuous batching, token-exactness first: every stream the
    // scheduler emits must equal its solo `generate` run before any
    // timing happens (admission, coalescing, and eviction are not
    // allowed to reach the tokens).
    for (m, label) in [(&dense, "dense"), (&compressed, "compressed")] {
        let pages = pool_pages_for(m, 6, p_len + fleet_new, 8);
        let mut sched = BatchScheduler::new(m, 8, pages, 3);
        let ids: Vec<usize> =
            (0..6).map(|i| sched.submit(&prompt(i, p_len), fleet_new)).collect();
        let done = sched.run_to_completion();
        for (i, id) in ids.iter().enumerate() {
            let c = done.iter().find(|c| c.id == *id).unwrap();
            assert_eq!(
                c.tokens,
                m.generate(&prompt(i, p_len), fleet_new),
                "{label}: scheduler stream {i} must match solo generate"
            );
        }
        println!("{:<44} ok", format!("continuous-batching token exactness ({label})"));
    }

    // --- Closed batch: the same 32-request workload as the fleet, but
    // coalesced into multi-row steps by the scheduler instead of one
    // thread per request. Coalescing amortizes every per-layer GEMM
    // dispatch across the whole batch, so it must at least match the
    // fleet path on the same hardware.
    {
        let ch = DEFAULT_PREFILL_CHUNK;
        serve_batched(&dense, requests, p_len, fleet_new, 0, ch);
        let (batch_dense_rps, _, dense_st) = {
            let a = serve_batched(&dense, requests, p_len, fleet_new, 0, ch);
            let b = serve_batched(&dense, requests, p_len, fleet_new, 0, ch);
            if a.0 >= b.0 { a } else { b }
        };
        let occ_dense = decode_occupancy(&dense_st);
        serve_batched(&compressed, requests, p_len, fleet_new, 0, ch);
        let (batch_comp_rps, _, _) = {
            let a = serve_batched(&compressed, requests, p_len, fleet_new, 0, ch);
            let b = serve_batched(&compressed, requests, p_len, fleet_new, 0, ch);
            if a.0 >= b.0 { a } else { b }
        };
        println!(
            "{:<44} {batch_dense_rps:.1} req/s  (occupancy {occ_dense:.1} rows/step)",
            format!("batched dense {requests} req")
        );
        println!(
            "{:<44} {batch_comp_rps:.1} req/s",
            format!("batched compressed {requests} req")
        );
        rec.metric("batch_dense_rps", batch_dense_rps);
        rec.metric("batch_dense_occupancy", occ_dense);
        rec.metric("batch_compressed_rps", batch_comp_rps);
        rec.metric("batch_vs_fleet_gain_dense", batch_dense_rps / dense_rps);
        assert!(
            batch_dense_rps >= dense_rps,
            "coalesced batching must not lose to the per-thread fleet: \
             {batch_dense_rps:.1} vs {dense_rps:.1} req/s"
        );
    }

    // --- Open-loop load: one arrival every 2 scheduler steps (fixed
    // in step units, replayable), so the batch fills and drains the
    // way live traffic would instead of starting full. Sustained
    // throughput and tail latency under load are the serving numbers
    // that matter at scale.
    for (m, label) in [(&dense, "dense"), (&compressed, "compressed")] {
        let ch = DEFAULT_PREFILL_CHUNK;
        serve_batched(m, requests, p_len, fleet_new, 2, ch);
        let (rps, lat, st) = {
            let a = serve_batched(m, requests, p_len, fleet_new, 2, ch);
            let b = serve_batched(m, requests, p_len, fleet_new, 2, ch);
            if a.0 >= b.0 { a } else { b }
        };
        let occ = decode_occupancy(&st);
        let (p50, p99) = (pct(&lat, 0.5), pct(&lat, 0.99));
        println!(
            "{:<44} {rps:.1} req/s  p50 {p50:.2} ms  p99 {p99:.2} ms  occ {occ:.1}",
            format!("open-loop {label} {requests} req / every 2 steps")
        );
        rec.metric(&format!("openloop_{label}_rps"), rps);
        rec.metric(&format!("openloop_{label}_p50_ms"), p50);
        rec.metric(&format!("openloop_{label}_p99_ms"), p99);
        rec.metric(&format!("openloop_{label}_prefill_rows"), st.prefill_rows as f64);
        rec.metric(&format!("openloop_{label}_prefill_chunks"), st.prefill_chunks as f64);
        rec.metric(&format!("openloop_{label}_mixed_steps"), st.mixed_steps as f64);
        rec.metric(&format!("openloop_{label}_pass_occupancy"), st.occupancy());
        rec.metric(
            &format!("openloop_{label}_lm_head_rows_saved"),
            st.lm_head_rows_saved as f64,
        );
        assert!(
            occ > 1.0,
            "{label}: open-loop arrivals must actually coalesce (occupancy {occ:.2})"
        );
        assert_eq!(
            st.lm_head_rows_saved,
            requests * (p_len - 1),
            "{label}: lazy prefill lm_head must skip every non-final prompt row"
        );
    }

    // --- Paged-KV capacity: under the same cache-memory budget (two
    // per-request max_seq slabs' worth of floats), short requests must
    // pack >= 4x more concurrent streams into the page pool than the
    // slab-per-request layout could ever hold.
    {
        let ps = 8usize;
        let slab_requests = 2usize;
        let d_head = dense.cfg.d_model / dense.cfg.n_heads;
        let slab_elems = 2 * dense.cfg.n_layers * dense.cfg.n_kv * dense.cfg.max_seq * d_head;
        let pool_pages = slab_requests * slab_elems / (ps * d_head);
        let n_req = 16usize;
        let mut sched = BatchScheduler::new(&dense, ps, pool_pages, n_req);
        let ids: Vec<usize> = (0..n_req).map(|i| sched.submit(&prompt(i, 4), 4)).collect();
        let done = sched.run_to_completion();
        assert_eq!(done.len(), n_req);
        for (i, id) in ids.iter().enumerate() {
            let c = done.iter().find(|c| c.id == *id).unwrap();
            assert_eq!(c.tokens, dense.generate(&prompt(i, 4), 4), "capacity request {i}");
        }
        let gain = sched.stats().peak_active as f64 / slab_requests as f64;
        println!(
            "{:<44} {gain:.1}x ({} live vs {slab_requests} slabs)",
            "paged-KV concurrent capacity gain",
            sched.stats().peak_active
        );
        rec.metric("paged_kv_capacity_gain", gain);
        assert!(
            gain >= 4.0,
            "paged KV must hold >= 4x the slab-equivalent request count, got {gain:.1}x"
        );
    }

    // --- Chunked prefill vs head-of-line blocking: an 8x-length
    // prompt lands mid-stream in otherwise-short open-loop traffic.
    // Unchunked (budget = usize::MAX), its whole 48-row prefill rides
    // one pass and every concurrent decode token stalls behind it;
    // chunked (budget 8), the prefill is spread over small mixed
    // passes. Token streams are asserted bit-equal across both
    // schedules and against solo `generate` BEFORE any timing; the
    // gate compares p99 per-token stall in deterministic pass-row
    // units (wall-clock stall is reported, never gated).
    {
        let chunk = 8usize;
        let (streams_c, stalls_c, st_c, _) = long_prompt_run(&dense, chunk);
        let (streams_u, stalls_u, st_u, _) = long_prompt_run(&dense, usize::MAX);
        assert_eq!(
            streams_c, streams_u,
            "chunked and unchunked schedules must emit bit-equal token streams"
        );
        for (i, s) in streams_c.iter().enumerate() {
            let p = if i == LONG_AT {
                prompt(999, LONG_LONG_LEN)
            } else {
                prompt(i, LONG_SHORT_LEN)
            };
            assert_eq!(s, &dense.generate(&p, LONG_N_NEW), "long-prompt stream {i} vs solo");
        }
        // Second (warm) runs for the reported wall-clock figures.
        let (_, _, _, wall_p99_c) = long_prompt_run(&dense, chunk);
        let (_, _, _, wall_p99_u) = long_prompt_run(&dense, usize::MAX);
        let (p99_c, p99_u) = (pct(&stalls_c, 0.99), pct(&stalls_u, 0.99));
        let saved = LONG_SHORTS * (LONG_SHORT_LEN - 1) + (LONG_LONG_LEN - 1);
        assert_eq!(st_c.lm_head_rows_saved, saved, "chunked lm_head row savings");
        assert_eq!(st_u.lm_head_rows_saved, saved, "unchunked lm_head row savings");
        assert!(st_c.mixed_steps > 0, "chunked prefill must overlap decode in mixed passes");
        assert!(
            p99_c < p99_u,
            "chunked prefill must strictly cut the p99 decode-token stall: \
             {p99_c:.0} vs {p99_u:.0} pass rows"
        );
        println!(
            "{:<44} p99 {p99_c:.0} vs {p99_u:.0} pass rows ({:.2} ms vs {:.2} ms/step wall)",
            "long-prompt stall, chunked vs unchunked", wall_p99_c, wall_p99_u
        );
        println!(
            "{:<44} {saved} rows ({} prefill chunks, {} mixed steps)",
            "lm_head rows saved by lazy prefill", st_c.prefill_chunks, st_c.mixed_steps
        );
        rec.metric("longprompt_chunked_p99_stall_rows", p99_c);
        rec.metric("longprompt_unchunked_p99_stall_rows", p99_u);
        rec.metric("longprompt_stall_reduction", p99_u / p99_c.max(1.0));
        rec.metric("longprompt_chunked_wall_p99_ms", wall_p99_c);
        rec.metric("longprompt_unchunked_wall_p99_ms", wall_p99_u);
        rec.metric("longprompt_lm_head_rows_saved", saved as f64);
        rec.metric("longprompt_chunked_prefill_chunks", st_c.prefill_chunks as f64);
        rec.metric("longprompt_chunked_mixed_steps", st_c.mixed_steps as f64);
        rec.metric("longprompt_chunked_pass_occupancy", st_c.occupancy());
        rec.metric("longprompt_unchunked_pass_occupancy", st_u.occupancy());
    }

    rec.write_json("BENCH_serve.json", "grail-serve-v2");
    println!("\ndone");
}
