//! Experiment-harness bench target: regenerates every paper table and
//! figure in `--quick` mode and times each. Requires `make artifacts`;
//! prints a skip notice otherwise (so `cargo bench` stays green on a
//! fresh clone).

use grail::coordinator::Artifacts;
use grail::exp::{ExpOptions, EXPERIMENTS};
use std::time::Instant;

fn main() {
    let artifacts = Artifacts::default_root();
    if artifacts.ensure_ready().is_err() {
        println!(
            "experiments bench: artifacts not built (run `make artifacts`) — skipping"
        );
        return;
    }
    let opts = ExpOptions {
        out_dir: "results/bench".into(),
        artifacts,
        quick: true,
        seed: 0,
    };
    println!("== regenerating all paper tables/figures (quick grids) ==\n");
    for (name, f) in EXPERIMENTS {
        let t0 = Instant::now();
        println!("---- {name} ----");
        match f(&opts) {
            Ok(()) => println!("{name}: {:.1}s\n", t0.elapsed().as_secs_f64()),
            Err(e) => println!("{name}: FAILED: {e:#}\n"),
        }
    }
}
