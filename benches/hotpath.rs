//! Hot-path microbenchmarks (custom harness — no criterion offline).
//!
//! Covers the kernels on the GRAIL critical path: the packed GEMM/SYRK
//! engine vs its scalar `*_ref` oracles (parity + speedup asserted, so
//! CI fails on a kernel or dispatch regression), Gram accumulation,
//! the ridge solve, conv-block forward, attention forward, and the
//! end-to-end compensation pipeline with packed kernels on vs off.
//! Results are also written machine-readably to `BENCH_hotpath.json`
//! so the perf trajectory is tracked across PRs. Perf targets and
//! before/after history live in EXPERIMENTS.md §Perf.

use grail::bench_util::{bench, layer_forwards, layer_forwards_reset, report_gflops, Recorder};
use grail::compress::{Reducer, Selector};
use grail::grail::{
    compress_model, compress_model_rescan, reconstruction, ActStats, CompressionSpec, Method,
};
use grail::nn::models::{LmBatch, LmConfig, MlpNet, TinyLm};
use grail::rng::Pcg64;
use grail::tensor::{gemm, ops, Tensor};

fn randn(rng: &mut Pcg64, shape: &[usize]) -> Tensor {
    let mut t = Tensor::zeros(shape);
    rng.fill_normal(t.data_mut(), 1.0);
    t
}

fn main() {
    let mut rng = Pcg64::seed(42);
    let mut rec = Recorder::default();
    println!("== grail hotpath benchmarks ==\n");

    // --- Packed GEMM engine vs scalar reference (the kernel surface).
    // Parity and speedup are *asserted*: a broken microkernel, packing
    // bug, or dispatch regression fails the bench (CI runs it).
    let gemm_shapes =
        [(64usize, 64usize, 64usize), (128, 128, 128), (256, 256, 256), (512, 512, 512)];
    for &(m, k, n) in &gemm_shapes {
        let a = randn(&mut rng, &[m, k]);
        let b = randn(&mut rng, &[k, n]);
        let bt = randn(&mut rng, &[n, k]);

        let mut c_ref = Tensor::zeros(&[m, n]);
        ops::gemm_acc_ref(a.data(), b.data(), c_ref.data_mut(), m, k, n, 1.0);
        let c_pack = ops::matmul(&a, &b);
        let diff = c_pack.max_abs_diff(&c_ref);
        assert!(diff < 1e-4 * (k as f32), "packed/scalar parity {m}x{k}x{n}: {diff}");

        let packed = bench(&format!("gemm_packed {m}x{k}x{n}"), 400, || ops::matmul(&a, &b));
        report_gflops(&packed, (2 * m * k * n) as f64);
        let scalar = bench(&format!("gemm_scalar {m}x{k}x{n}"), 400, || {
            let mut c = Tensor::zeros(&[m, n]);
            ops::gemm_acc_ref(a.data(), b.data(), c.data_mut(), m, k, n, 1.0);
            c
        });
        let speedup = scalar.median_ns / packed.median_ns;
        println!("{:<44} {:.2}x", format!("packed gemm speedup {m}x{k}x{n}"), speedup);
        rec.push(&packed);
        rec.push(&scalar);
        rec.metric(&format!("gemm_packed_speedup_{m}"), speedup);
        if m >= 256 {
            assert!(packed.median_ns < scalar.median_ns, "packed must win at {m}-dim GEMM");
        }
        if m == 512 {
            assert!(speedup >= 2.0, "packed must be >= 2x on 512-dim GEMM, got {speedup:.2}x");
        }

        let packed_nt =
            bench(&format!("gemm_nt_packed {m}x{k}x{n}"), 400, || ops::matmul_nt(&a, &bt));
        let scalar_nt = bench(&format!("gemm_nt_scalar {m}x{k}x{n}"), 400, || {
            let mut c = Tensor::zeros(&[m, n]);
            ops::gemm_nt_acc_ref(a.data(), bt.data(), c.data_mut(), m, k, n);
            c
        });
        let nt_speedup = scalar_nt.median_ns / packed_nt.median_ns;
        println!("{:<44} {:.2}x", format!("packed gemm_nt speedup {m}x{k}x{n}"), nt_speedup);
        rec.push(&packed_nt);
        rec.push(&scalar_nt);
        rec.metric(&format!("gemm_nt_packed_speedup_{m}"), nt_speedup);
        if m >= 256 {
            assert!(
                packed_nt.median_ns < scalar_nt.median_ns,
                "packed must win at {m}-dim GEMM-NT"
            );
        }
    }

    // --- Packed SYRK vs scalar reference (streamed Gram accumulation).
    for &(n, h) in &[(2048usize, 64usize), (1024, 128), (1024, 256)] {
        let x = randn(&mut rng, &[n, h]);
        let mut g_ref = Tensor::zeros(&[h, h]);
        ops::syrk_upper_acc_ref(&x, &mut g_ref);
        let mut g_pack = Tensor::zeros(&[h, h]);
        ops::syrk_upper_acc(&x, &mut g_pack);
        let diff = g_pack.max_abs_diff(&g_ref);
        assert!(diff < 1e-4 * (n as f32), "packed/scalar SYRK parity n={n} h={h}: {diff}");

        let packed = bench(&format!("syrk_packed n={n} h={h}"), 300, || {
            let mut g = Tensor::zeros(&[h, h]);
            ops::syrk_upper_acc(&x, &mut g);
            g
        });
        report_gflops(&packed, (n * h * (h + 1)) as f64);
        let scalar = bench(&format!("syrk_scalar n={n} h={h}"), 300, || {
            let mut g = Tensor::zeros(&[h, h]);
            ops::syrk_upper_acc_ref(&x, &mut g);
            g
        });
        let speedup = scalar.median_ns / packed.median_ns;
        println!("{:<44} {:.2}x", format!("packed syrk speedup n={n} h={h}"), speedup);
        rec.push(&packed);
        rec.push(&scalar);
        rec.metric(&format!("syrk_packed_speedup_h{h}"), speedup);
        if h >= 256 {
            assert!(packed.median_ns < scalar.median_ns, "packed must win at h={h} SYRK");
        }
    }

    // --- Zero-heavy (post-ReLU-shaped) Gram accumulation must cost
    // what dense accumulation costs: the packed kernels have no
    // data-dependent branch, so there is no rescan to pay (the old
    // zero-skip re-scanned the whole buffer for finiteness on every
    // zero-bearing call).
    {
        let (n, h) = (2048usize, 128usize);
        let dense = randn(&mut rng, &[n, h]);
        let mut relu = dense.clone();
        for v in relu.data_mut().iter_mut() {
            if *v < 0.0 {
                *v = 0.0;
            }
        }
        let d = bench("syrk dense n=2048 h=128", 300, || {
            let mut g = Tensor::zeros(&[h, h]);
            ops::syrk_upper_acc(&dense, &mut g);
            g
        });
        let z = bench("syrk zero-heavy n=2048 h=128", 300, || {
            let mut g = Tensor::zeros(&[h, h]);
            ops::syrk_upper_acc(&relu, &mut g);
            g
        });
        let ratio = z.median_ns / d.median_ns;
        println!("{:<44} {:.2}x", "zero-heavy / dense syrk cost ratio", ratio);
        rec.push(&d);
        rec.push(&z);
        rec.metric("syrk_zero_heavy_cost_ratio", ratio);
        assert!(ratio < 1.5, "zero-heavy Gram accumulation must not pay a rescan: {ratio:.2}x");
    }

    // --- Gram accumulation at pipeline tap geometries.
    for &(n, h) in &[(1024usize, 64usize), (1024, 192), (4096, 256)] {
        let x = randn(&mut rng, &[n, h]);
        let r = bench(&format!("gram_syrk n={n} h={h}"), 300, || {
            let mut g = Tensor::zeros(&[h, h]);
            ops::syrk_upper_acc(&x, &mut g);
            ops::symmetrize_from_upper(&mut g);
            g
        });
        // SYRK flops: n·h·(h+1) (half matrix, fma=2 flops).
        report_gflops(&r, (n * h * (h + 1)) as f64);
        rec.push(&r);
    }

    // --- Ridge reconstruction solve (B = G_PH^T (G_PP+λI)^-1)
    for &(h, kk) in &[(192usize, 96usize), (256, 64)] {
        let x = randn(&mut rng, &[512, h]);
        let stats = ActStats::from_acts(&x);
        let reducer = Reducer::Select((0..kk).collect());
        let r = bench(&format!("ridge_reconstruction h={h} k={kk}"), 300, || {
            reconstruction(&stats.gram, &reducer, 1, 1e-3)
        });
        rec.push(&r);
    }

    // --- Blocked vs scalar SPD solve on solve-dominated deep-model
    // geometries: K×K ridge system (K = kept units) against all H
    // right-hand sides — the per-site cost that dominates the closed
    // loop at depth. Same f64 precision both ways; only blocking,
    // panelization, and RHS fan-out differ.
    for &(n, m) in &[(256usize, 256usize), (384, 512)] {
        let x = randn(&mut rng, &[2 * n + 5, n]);
        let mut a = ops::gram(&x);
        for i in 0..n {
            let v = a.at2(i, i) + (n as f32);
            a.set2(i, i, v);
        }
        let b = randn(&mut rng, &[n, m]);
        let blocked = bench(&format!("solve_spd_multi blocked n={n} rhs={m}"), 600, || {
            grail::linalg::solve_spd_multi(&a, &b)
        });
        let scalar = bench(&format!("solve_spd_multi scalar  n={n} rhs={m}"), 600, || {
            grail::linalg::solve_spd_multi_ref(&a, &b)
        });
        println!(
            "{:<44} {:.2}x",
            format!("blocked solve speedup n={n} rhs={m}"),
            scalar.median_ns / blocked.median_ns
        );
        rec.push(&blocked);
        rec.push(&scalar);
        rec.metric(&format!("blocked_solve_speedup_n{n}"), scalar.median_ns / blocked.median_ns);
        let fast = grail::linalg::solve_spd_multi(&a, &b);
        let slow = grail::linalg::solve_spd_multi_ref(&a, &b);
        let diff = fast.max_abs_diff(&slow);
        assert!(diff < 1e-3, "blocked vs scalar diverged: {diff}");
        assert!(blocked.median_ns < scalar.median_ns, "blocked must beat scalar");
    }

    // --- Conv block forward (MiniResNet block1 geometry)
    {
        let conv = grail::nn::Conv2d::init(32, 32, 3, 1, 1, &mut rng);
        let x = randn(&mut rng, &[32, 32 * 16 * 16]);
        let r = bench("conv2d 32x32x16x16 k3", 400, || conv.forward(&x, 16, 16));
        // 2 * N * O * C * kh * kw * OH * OW
        report_gflops(&r, 2.0 * 32.0 * 32.0 * 32.0 * 9.0 * 256.0);
        rec.push(&r);
    }

    // --- Attention forward (TinyLm block geometry)
    {
        let attn = grail::nn::MultiHeadAttention::init(64, 8, 8, 8, true, &mut rng);
        let x = randn(&mut rng, &[16 * 32, 64]);
        let r = bench("attention b=16 t=32 h=8 dh=8", 400, || attn.forward(&x, 16, 32));
        rec.push(&r);
    }

    // --- End-to-end staged pipeline, packed kernels on vs off. Same
    // spec, same shards, same solver — only the f32 forward/Gram
    // kernels differ, so this is the tentpole's wall-clock bottom line.
    {
        let model = MlpNet::init(768, 256, 10, &mut rng);
        let calib = randn(&mut rng, &[512, 768]);
        let mut cfg = CompressionSpec::uniform(Method::Prune(Selector::Wanda), 0.5, true);
        cfg.shards = 8;
        let packed = bench("pipeline mlp staged packed kernels", 800, || {
            let mut m = model.clone();
            compress_model(&mut m, &calib, &cfg)
        });
        gemm::set_packed_enabled(false);
        let scalar = bench("pipeline mlp staged scalar kernels", 800, || {
            let mut m = model.clone();
            compress_model(&mut m, &calib, &cfg)
        });
        gemm::set_packed_enabled(true);
        let speedup = scalar.median_ns / packed.median_ns;
        println!("{:<44} {:.2}x", "staged pipeline packed-kernel speedup", speedup);
        rec.push(&packed);
        rec.push(&scalar);
        rec.metric("staged_pipeline_packed_speedup", speedup);
        // 5% noise allowance: the pipeline mixes GEMM with solves and
        // selection, so on a loaded shared runner the medians can sit
        // closer than the kernel-level sweeps; the gate still catches
        // any real end-to-end regression.
        assert!(
            packed.median_ns < scalar.median_ns * 1.05,
            "packed kernels must not lose the staged pipeline end-to-end ({speedup:.2}x)"
        );
    }

    // --- End-to-end compensation pipeline (MLP, both sites)
    {
        let model = MlpNet::init(768, 256, 10, &mut rng);
        let calib = randn(&mut rng, &[128, 768]);
        let r = bench("pipeline mlp wanda+grail r=0.5", 500, || {
            let mut m = model.clone();
            let cfg = CompressionSpec::uniform(Method::Prune(Selector::Wanda), 0.5, true);
            compress_model(&mut m, &calib, &cfg)
        });
        rec.push(&r);
    }

    // --- TinyLm forward (the eval hot path)
    {
        let lm = TinyLm::init(LmConfig::default(), &mut rng);
        let toks: Vec<u16> = (0..16 * 33).map(|i| (i % 64) as u16).collect();
        let ts = grail::data::TokenSet { tokens: toks, vocab: 64 };
        let batch = LmBatch::from_tokens(&ts, 32, 16);
        let r = bench("tinylm_forward b=16 t=32", 500, || lm.forward(&batch));
        rec.push(&r);
    }

    // --- Closed-loop calibration: staged O(L) segment executor vs the
    // per-site rescan reference (O(L²) layer forwards). Same shards,
    // same statistics, bit-identical Report.sites — only the execution
    // strategy differs. Depths: 4/8/16 sites on the TinyLm family.
    for &layers in &[2usize, 4, 8] {
        let n_sites = 2 * layers;
        let cfg_lm = LmConfig { n_layers: layers, ..Default::default() };
        let lm = TinyLm::init(cfg_lm, &mut rng);
        let toks: Vec<u16> = (0..16 * 33).map(|i| (i % 64) as u16).collect();
        let ts = grail::data::TokenSet { tokens: toks, vocab: 64 };
        let batch = LmBatch::from_tokens(&ts, 32, 16);
        let cfg = CompressionSpec::uniform(Method::Prune(Selector::Wanda), 0.5, true);

        let staged = bench(&format!("pipeline lm staged sites={n_sites}"), 1200, || {
            let mut m = lm.clone();
            compress_model(&mut m, &batch, &cfg)
        });
        let rescan = bench(&format!("pipeline lm rescan sites={n_sites}"), 1200, || {
            let mut m = lm.clone();
            compress_model_rescan(&mut m, &batch, &cfg)
        });
        println!(
            "{:<44} {:.2}x",
            format!("staged speedup over rescan sites={n_sites}"),
            rescan.median_ns / staged.median_ns
        );
        rec.push(&staged);
        rec.push(&rescan);
        rec.metric(
            &format!("staged_vs_rescan_speedup_sites{n_sites}"),
            rescan.median_ns / staged.median_ns,
        );

        // Layer-forward counts (single shard/worker so the counter
        // reflects segment executions, not sharding) + outcome parity.
        let mut count_cfg = cfg.clone();
        count_cfg.shards = 1;
        count_cfg.workers = 1;
        let mut a = lm.clone();
        layer_forwards_reset();
        let ra = compress_model(&mut a, &batch, &count_cfg);
        let staged_fwd = layer_forwards();
        let mut b = lm.clone();
        layer_forwards_reset();
        let rb = compress_model_rescan(&mut b, &batch, &count_cfg);
        let rescan_fwd = layer_forwards();
        println!(
            "{:<44} staged {staged_fwd} vs rescan {rescan_fwd}",
            format!("layer forwards sites={n_sites}")
        );
        assert!(staged_fwd < rescan_fwd, "staged must do fewer layer forwards");
        assert_eq!(ra.sites.len(), rb.sites.len());
        for (x, y) in ra.sites.iter().zip(&rb.sites) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.units_before, y.units_before);
            assert_eq!(x.units_after, y.units_after);
            assert_eq!(
                x.recon_err.to_bits(),
                y.recon_err.to_bits(),
                "site {}: staged and rescan outcomes must be identical",
                x.id
            );
        }
    }

    rec.write_json("BENCH_hotpath.json", "grail-hotpath-v1");
    println!("\ndone");
}
