//! Hot-path microbenchmarks (custom harness — no criterion offline).
//!
//! Covers the kernels on the GRAIL critical path: Gram accumulation
//! (SYRK), the ridge solve, GEMM variants, conv-block forward,
//! attention forward, and the end-to-end compensation pipeline on an
//! in-memory model. Perf targets and before/after history live in
//! EXPERIMENTS.md §Perf.

use grail::bench_util::{bench, layer_forwards, layer_forwards_reset, report_gflops};
use grail::compress::{Reducer, Selector};
use grail::grail::{
    compress_model, compress_model_rescan, reconstruction, ActStats, Method, CompressionSpec,
};
use grail::nn::models::{LmBatch, LmConfig, MlpNet, TinyLm};
use grail::rng::Pcg64;
use grail::tensor::{ops, Tensor};

fn randn(rng: &mut Pcg64, shape: &[usize]) -> Tensor {
    let mut t = Tensor::zeros(shape);
    rng.fill_normal(t.data_mut(), 1.0);
    t
}

fn main() {
    let mut rng = Pcg64::seed(42);
    println!("== grail hotpath benchmarks ==\n");

    // --- Gram accumulation (the paper's O(N·H²) calibration step)
    for &(n, h) in &[(1024usize, 64usize), (1024, 192), (4096, 256)] {
        let x = randn(&mut rng, &[n, h]);
        let r = bench(&format!("gram_syrk n={n} h={h}"), 300, || {
            let mut g = Tensor::zeros(&[h, h]);
            ops::syrk_upper_acc(&x, &mut g);
            ops::symmetrize_from_upper(&mut g);
            g
        });
        // SYRK flops: n·h·(h+1) (half matrix, fma=2 flops).
        report_gflops(&r, (n * h * (h + 1)) as f64);
    }

    // --- GEMM variants
    for &(m, k, n) in &[(256usize, 256usize, 256usize), (512, 512, 512)] {
        let a = randn(&mut rng, &[m, k]);
        let b = randn(&mut rng, &[k, n]);
        let r = bench(&format!("gemm {m}x{k}x{n}"), 400, || ops::matmul(&a, &b));
        report_gflops(&r, (2 * m * k * n) as f64);
        let bt = randn(&mut rng, &[n, k]);
        let r = bench(&format!("gemm_nt {m}x{k}x{n}"), 400, || ops::matmul_nt(&a, &bt));
        report_gflops(&r, (2 * m * k * n) as f64);
    }

    // --- Ridge reconstruction solve (B = G_PH^T (G_PP+λI)^-1)
    for &(h, kk) in &[(192usize, 96usize), (256, 64)] {
        let x = randn(&mut rng, &[512, h]);
        let stats = ActStats::from_acts(&x);
        let reducer = Reducer::Select((0..kk).collect());
        bench(&format!("ridge_reconstruction h={h} k={kk}"), 300, || {
            reconstruction(&stats.gram, &reducer, 1, 1e-3)
        });
    }

    // --- Blocked vs scalar SPD solve on solve-dominated deep-model
    // geometries: K×K ridge system (K = kept units) against all H
    // right-hand sides — the per-site cost that dominates the closed
    // loop at depth. Same f64 precision both ways; only blocking,
    // panelization, and RHS fan-out differ.
    for &(n, m) in &[(256usize, 256usize), (384, 512)] {
        let x = randn(&mut rng, &[2 * n + 5, n]);
        let mut a = ops::gram(&x);
        for i in 0..n {
            let v = a.at2(i, i) + (n as f32);
            a.set2(i, i, v);
        }
        let b = randn(&mut rng, &[n, m]);
        let blocked = bench(&format!("solve_spd_multi blocked n={n} rhs={m}"), 600, || {
            grail::linalg::solve_spd_multi(&a, &b)
        });
        let scalar = bench(&format!("solve_spd_multi scalar  n={n} rhs={m}"), 600, || {
            grail::linalg::solve_spd_multi_ref(&a, &b)
        });
        println!(
            "{:<44} {:.2}x",
            format!("blocked solve speedup n={n} rhs={m}"),
            scalar.median_ns / blocked.median_ns
        );
        let fast = grail::linalg::solve_spd_multi(&a, &b);
        let slow = grail::linalg::solve_spd_multi_ref(&a, &b);
        let diff = fast.max_abs_diff(&slow);
        assert!(diff < 1e-3, "blocked vs scalar diverged: {diff}");
        assert!(blocked.median_ns < scalar.median_ns, "blocked must beat scalar");
    }

    // --- Conv block forward (MiniResNet block1 geometry)
    {
        let conv = grail::nn::Conv2d::init(32, 32, 3, 1, 1, &mut rng);
        let x = randn(&mut rng, &[32, 32 * 16 * 16]);
        let r = bench("conv2d 32x32x16x16 k3", 400, || conv.forward(&x, 16, 16));
        // 2 * N * O * C * kh * kw * OH * OW
        report_gflops(&r, 2.0 * 32.0 * 32.0 * 32.0 * 9.0 * 256.0);
    }

    // --- Attention forward (TinyLm block geometry)
    {
        let attn = grail::nn::MultiHeadAttention::init(64, 8, 8, 8, true, &mut rng);
        let x = randn(&mut rng, &[16 * 32, 64]);
        bench("attention b=16 t=32 h=8 dh=8", 400, || attn.forward(&x, 16, 32));
    }

    // --- End-to-end compensation pipeline (MLP, both sites)
    {
        let model = MlpNet::init(768, 256, 10, &mut rng);
        let calib = randn(&mut rng, &[128, 768]);
        bench("pipeline mlp wanda+grail r=0.5", 500, || {
            let mut m = model.clone();
            let cfg = CompressionSpec::uniform(Method::Prune(Selector::Wanda), 0.5, true);
            compress_model(&mut m, &calib, &cfg)
        });
    }

    // --- TinyLm forward (the eval hot path)
    {
        let lm = TinyLm::init(LmConfig::default(), &mut rng);
        let toks: Vec<u16> = (0..16 * 33).map(|i| (i % 64) as u16).collect();
        let ts = grail::data::TokenSet { tokens: toks, vocab: 64 };
        let batch = LmBatch::from_tokens(&ts, 32, 16);
        bench("tinylm_forward b=16 t=32", 500, || lm.forward(&batch));
    }

    // --- Closed-loop calibration: staged O(L) segment executor vs the
    // per-site rescan reference (O(L²) layer forwards). Same shards,
    // same statistics, bit-identical Report.sites — only the execution
    // strategy differs. Depths: 4/8/16 sites on the TinyLm family.
    for &layers in &[2usize, 4, 8] {
        let n_sites = 2 * layers;
        let cfg_lm = LmConfig { n_layers: layers, ..Default::default() };
        let lm = TinyLm::init(cfg_lm, &mut rng);
        let toks: Vec<u16> = (0..16 * 33).map(|i| (i % 64) as u16).collect();
        let ts = grail::data::TokenSet { tokens: toks, vocab: 64 };
        let batch = LmBatch::from_tokens(&ts, 32, 16);
        let cfg = CompressionSpec::uniform(Method::Prune(Selector::Wanda), 0.5, true);

        let staged = bench(&format!("pipeline lm staged sites={n_sites}"), 1200, || {
            let mut m = lm.clone();
            compress_model(&mut m, &batch, &cfg)
        });
        let rescan = bench(&format!("pipeline lm rescan sites={n_sites}"), 1200, || {
            let mut m = lm.clone();
            compress_model_rescan(&mut m, &batch, &cfg)
        });
        println!(
            "{:<44} {:.2}x",
            format!("staged speedup over rescan sites={n_sites}"),
            rescan.median_ns / staged.median_ns
        );

        // Layer-forward counts (single shard/worker so the counter
        // reflects segment executions, not sharding) + outcome parity.
        let mut count_cfg = cfg.clone();
        count_cfg.shards = 1;
        count_cfg.workers = 1;
        let mut a = lm.clone();
        layer_forwards_reset();
        let ra = compress_model(&mut a, &batch, &count_cfg);
        let staged_fwd = layer_forwards();
        let mut b = lm.clone();
        layer_forwards_reset();
        let rb = compress_model_rescan(&mut b, &batch, &count_cfg);
        let rescan_fwd = layer_forwards();
        println!(
            "{:<44} staged {staged_fwd} vs rescan {rescan_fwd}",
            format!("layer forwards sites={n_sites}")
        );
        assert!(staged_fwd < rescan_fwd, "staged must do fewer layer forwards");
        assert_eq!(ra.sites.len(), rb.sites.len());
        for (x, y) in ra.sites.iter().zip(&rb.sites) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.units_before, y.units_before);
            assert_eq!(x.units_after, y.units_after);
            assert_eq!(
                x.recon_err.to_bits(),
                y.recon_err.to_bits(),
                "site {}: staged and rescan outcomes must be identical",
                x.id
            );
        }
    }
    println!("\ndone");
}
