//! Statistics-cache benchmarks (custom harness — no criterion
//! offline).
//!
//! Measures what the content-addressed ActStats cache actually buys:
//! warm-cache plan resolution (gram-sensitivity allocator and the full
//! calibration-driven search) against the cold streamed pass over the
//! dense model. The warm path is first *asserted* forward-free (the
//! global layer-forward counter stays at zero) and bit-identical to
//! the cold plan; then the ≥ 2× speed claim is asserted so CI fails if
//! the cache ever stops paying for itself. Results land
//! machine-readably in `BENCH_cache.json` (schema `grail-cache-v1`);
//! reproduction steps in EXPERIMENTS.md §Serve daemon.

use std::sync::Arc;

use grail::bench_util::{bench, layer_forwards, layer_forwards_reset, Recorder};
use grail::compress::Selector;
use grail::data::SynthVision;
use grail::grail::{plan_for_model, BudgetMode, CompressionSpec, Method, SearchSeed};
use grail::nn::models::MlpNet;
use grail::rng::Pcg64;
use grail::serve::digest::digest_bytes;
use grail::serve::provider::{self, StatsContext};
use grail::serve::StatsCache;

fn main() {
    println!("== statistics cache: warm vs cold plan resolution ==\n");
    let mut rec = Recorder::default();

    // Statistics-dominated workload: a wide calibration batch makes
    // the streamed pass (GEMM forwards + Gram accumulation) the cost
    // center, while the allocator/search arithmetic on the tiny
    // per-site Grams is cheap — exactly the serving regime the cache
    // targets.
    let m = MlpNet::init(768, 48, 10, &mut Pcg64::seed(7));
    let x = SynthVision::new(9).generate(1024).x;

    let mut sens = CompressionSpec::uniform(Method::Prune(Selector::Wanda), 0.5, true);
    sens.budget = BudgetMode::GramSensitivity { target_ratio: 0.5 };
    sens.shards = 4;
    sens.workers = 1;

    let mut tune = sens.clone();
    tune.budget =
        BudgetMode::Search { target_ratio: 0.5, alpha_grid: vec![1e-4, 5e-3], rounds: 1 };
    tune.search_seed = SearchSeed::GramSensitivity;

    // Cold reference plans and timings: no provider installed, every
    // iteration pays the full calibration pass.
    let cold_sens_plan = plan_for_model(&m, &x, &sens).unwrap();
    let cold_tune_plan = plan_for_model(&m, &x, &tune).unwrap();
    let cold_sens = bench("plan/gram-sensitivity/cold", 400, || {
        plan_for_model(&m, &x, &sens).unwrap()
    });
    let cold_tune = bench("tune/search/cold", 400, || plan_for_model(&m, &x, &tune).unwrap());

    // Warm side: install the provider, populate on the first pass,
    // then verify the contract before timing it — zero calibration
    // layer forwards and bit-identical plans.
    let root = std::env::temp_dir().join(format!("grail_bench_cache_{}", std::process::id()));
    std::fs::remove_dir_all(&root).ok();
    let cache = Arc::new(StatsCache::open(&root).unwrap());
    let _scope = provider::install(StatsContext::new(
        cache.clone(),
        digest_bytes(b"bench-mlp-768x48"),
        digest_bytes(b"bench-vision-1024"),
    ));
    plan_for_model(&m, &x, &sens).unwrap();
    plan_for_model(&m, &x, &tune).unwrap();
    assert!(cache.misses() > 0, "populate pass must go through the cache");

    layer_forwards_reset();
    let warm_sens_plan = plan_for_model(&m, &x, &sens).unwrap();
    let warm_tune_plan = plan_for_model(&m, &x, &tune).unwrap();
    assert_eq!(
        layer_forwards(),
        0,
        "warm-cache plan resolution must skip every calibration layer forward"
    );
    assert_eq!(
        warm_sens_plan.to_toml(),
        cold_sens_plan.to_toml(),
        "warm gram-sensitivity plan diverged from cold"
    );
    assert_eq!(
        warm_tune_plan.to_toml(),
        cold_tune_plan.to_toml(),
        "warm search winner diverged from cold"
    );
    assert!(cache.hits() > 0, "verification passes must be served from the cache");

    let warm_sens = bench("plan/gram-sensitivity/warm", 400, || {
        plan_for_model(&m, &x, &sens).unwrap()
    });
    let warm_tune = bench("tune/search/warm", 400, || plan_for_model(&m, &x, &tune).unwrap());

    let sens_speedup = cold_sens.median_ns / warm_sens.median_ns;
    let tune_speedup = cold_tune.median_ns / warm_tune.median_ns;
    println!("\nplan warm speedup {sens_speedup:.1}x · tune warm speedup {tune_speedup:.1}x");
    assert!(
        sens_speedup >= 2.0,
        "warm gram-sensitivity resolution must be ≥ 2x cold (got {sens_speedup:.2}x)"
    );
    assert!(
        tune_speedup >= 2.0,
        "warm search must be ≥ 2x cold (got {tune_speedup:.2}x)"
    );

    rec.push(&cold_sens);
    rec.push(&warm_sens);
    rec.push(&cold_tune);
    rec.push(&warm_tune);
    rec.metric("plan_warm_speedup", sens_speedup);
    rec.metric("tune_warm_speedup", tune_speedup);
    rec.metric("cache_entry_hits", cache.hits() as f64);
    rec.metric("cache_entry_misses", cache.misses() as f64);
    rec.write_json("BENCH_cache.json", "grail-cache-v1");
    std::fs::remove_dir_all(&root).ok();
}
