//! Head-structured compression on grouped-query attention — the paper
//! §3.2 constraint demo: reductions act at the head level through the
//! Kronecker lift `R ⊗ I_dh`, and GQA forces a block-diagonal reducer
//! (equal head counts per KV group).
//!
//! ```bash
//! cargo run --release --example gqa_heads
//! ```

use anyhow::Result;
use grail::compress::baselines::Baseline;
use grail::coordinator::{Artifacts, Zoo};
use grail::data::io::read_tokens;
use grail::eval::lm_perplexity;
use grail::grail::{compress_model, Method, CompressionSpec};
use grail::nn::models::LmBatch;

fn main() -> Result<()> {
    let art = Artifacts::default_root();
    let zoo = Zoo::open(art.clone())?;
    let calib_toks = read_tokens(&art.data("text_calib.tokens"))?;
    let calib = LmBatch::from_tokens(&calib_toks, 32, 128);
    let eval = read_tokens(&art.data("text_wt2s.tokens"))?;

    for name in ["tinylm_mha", "tinylm_gqa"] {
        let model = zoo.lm(name)?;
        let attn = &model.blocks[0].attn;
        println!(
            "== {name}: {} query heads, {} KV heads (group size {}) ==",
            attn.n_heads,
            attn.n_kv,
            attn.group_size()
        );
        let dense = lm_perplexity(&model, &eval, 32, 96, 16);
        println!("   dense ppl {dense:.2}");
        for ratio in [0.25, 0.5] {
            for grail in [false, true] {
                let mut m = model.clone();
                let cfg = CompressionSpec::uniform(Method::Baseline(Baseline::Wanda), ratio, grail);
                let rep = compress_model(&mut m, &calib, &cfg);
                let ppl = lm_perplexity(&m, &eval, 32, 96, 16);
                // Verify every attention site kept equal heads per group.
                let h0 = m.blocks[0].attn.n_heads;
                println!(
                    "   ratio {ratio:.2} grail={grail:<5} -> {h0} heads/block, \
                     ppl {ppl:.2} (mean recon err {:.3})",
                    rep.mean_recon_err()
                );
            }
        }
        println!();
    }
    Ok(())
}
