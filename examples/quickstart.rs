//! Quickstart: compress a trained model with any selector and restore
//! its behaviour with GRAIL — no labels, no gradients, one linear
//! solve per block.
//!
//! ```bash
//! make artifacts            # once: data, training, AOT export
//! cargo run --release --example quickstart
//! ```

use anyhow::Result;
use grail::compress::Selector;
use grail::coordinator::{Artifacts, Zoo};
use grail::data::io::read_images;
use grail::eval::vision_accuracy;
use grail::grail::{compress_model, Method, CompressionSpec};

fn main() -> Result<()> {
    let art = Artifacts::default_root();
    let zoo = Zoo::open(art.clone())?;

    // A checkpoint trained by the build step, plus unlabeled
    // calibration images and a held-out test set.
    let model = zoo.mlp("mlp_seed0")?;
    let calib = read_images(&art.data("vision_calib.imgs"))?.slice(0, 256);
    let test = read_images(&art.data("vision_test.imgs"))?;

    let dense_acc = vision_accuracy(|x| model.forward(x), &test, 128);
    println!("dense accuracy:              {dense_acc:.4}");

    // Prune 50% of every hidden layer with magnitude-L2 — no recovery.
    let mut pruned = model.clone();
    let cfg = CompressionSpec::uniform(Method::Prune(Selector::MagnitudeL2), 0.5, false);
    compress_model(&mut pruned, &calib.x, &cfg);
    let pruned_acc = vision_accuracy(|x| pruned.forward(x), &test, 128);
    println!("pruned 50% (no recovery):    {pruned_acc:.4}");

    // Same selection + GRAIL: Gram statistics from 128 unlabeled
    // images, ridge reconstruction, merged into the consumer weights.
    let mut compensated = model.clone();
    let cfg = CompressionSpec::uniform(Method::Prune(Selector::MagnitudeL2), 0.5, true);
    let report = compress_model(&mut compensated, &calib.x, &cfg);
    let grail_acc = vision_accuracy(|x| compensated.forward(x), &test, 128);
    println!("pruned 50% + GRAIL:          {grail_acc:.4}");
    println!(
        "\nGRAIL recovered {:+.1} points using {} calibration images",
        100.0 * (grail_acc - pruned_acc),
        calib.len()
    );
    for s in &report.sites {
        println!(
            "  site {}: {} -> {} units, relative reconstruction error {:.3}",
            s.id, s.units_before, s.units_after, s.recon_err
        );
    }
    println!("  {}", report.summary());
    println!(
        "  calibration {:.3}s, compensation {:.3}s (no labels, no gradients)",
        report.calib_seconds, report.comp_seconds
    );
    Ok(())
}
