//! Folding vs pruning under GRAIL (the paper's central comparison on
//! vision models): sweeps a MiniResNet and a TinyViT through both
//! reduction families at several ratios and prints the four curves —
//! {prune, fold} × {data-free, +GRAIL}.
//!
//! ```bash
//! cargo run --release --example folding_vs_pruning
//! ```

use anyhow::Result;
use grail::compress::Selector;
use grail::coordinator::{Artifacts, Zoo};
use grail::data::io::read_images;
use grail::eval::vision_accuracy;
use grail::grail::{compress_model, Method, CompressionSpec};

fn main() -> Result<()> {
    let art = Artifacts::default_root();
    let zoo = Zoo::open(art.clone())?;
    let calib = read_images(&art.data("vision_calib.imgs"))?.slice(0, 128);
    let test = read_images(&art.data("vision_test.imgs"))?.slice(0, 512);

    for family in ["resnet", "vit"] {
        println!("== {family} ==");
        println!(
            "{:<6} {:>12} {:>12} {:>12} {:>12}",
            "ratio", "prune", "prune+GRAIL", "fold", "fold+GRAIL"
        );
        for ratio in [0.2, 0.4, 0.6, 0.8] {
            let mut cells = Vec::new();
            for (method, grail) in [
                (Method::Prune(Selector::MagnitudeL2), false),
                (Method::Prune(Selector::MagnitudeL2), true),
                (Method::Fold, false),
                (Method::Fold, true),
            ] {
                let cfg = CompressionSpec::uniform(method, ratio, grail);
                let acc = match family {
                    "resnet" => {
                        let mut m = zoo.resnet("resnet_seed0")?;
                        compress_model(&mut m, &calib.x, &cfg);
                        vision_accuracy(|x| m.forward(x), &test, 128)
                    }
                    _ => {
                        let mut m = zoo.vit("vit_seed0")?;
                        compress_model(&mut m, &calib.x, &cfg);
                        vision_accuracy(|x| m.forward(x), &test, 128)
                    }
                };
                cells.push(acc);
            }
            println!(
                "{:<6.1} {:>12.4} {:>12.4} {:>12.4} {:>12.4}",
                ratio, cells[0], cells[1], cells[2], cells[3]
            );
        }
        println!();
    }
    println!("expected shape (paper Figs. 2/3/5): GRAIL lifts both families;");
    println!("compensated folding trails compensated pruning on the ViT.");
    Ok(())
}
