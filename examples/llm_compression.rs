//! End-to-end driver (DESIGN.md: the full-system validation run).
//!
//! Loads the trained TinyLm checkpoint, serves batched evaluation
//! requests through the PJRT runtime at full width (the fixed-shape
//! AOT hot path), then runs the complete GRAIL pipeline — baseline
//! structured pruning at head+MLP level with closed-loop Gram
//! compensation — and reports perplexity on all three eval splits plus
//! latency/throughput of both paths. The run is recorded in
//! EXPERIMENTS.md §End-to-end.
//!
//! ```bash
//! cargo run --release --example llm_compression
//! ```

use anyhow::Result;
use grail::compress::baselines::Baseline;
use grail::coordinator::{Artifacts, Zoo};
use grail::data::io::read_tokens;
use grail::data::TextSplit;
use grail::eval::lm_perplexity;
use grail::grail::{compress_model, Method, CompressionSpec};
use grail::nn::models::LmBatch;
use grail::runtime::Runtime;
use std::time::Instant;

const SEQ: usize = 32;

fn main() -> Result<()> {
    let art = Artifacts::default_root();
    let zoo = Zoo::open(art.clone())?;
    let model = zoo.lm("tinylm_mha")?;

    // ---- 1. PJRT hot path: the AOT-compiled full-width forward.
    let mut rt = Runtime::cpu(art.clone())?;
    let calib_toks = read_tokens(&art.data("text_calib.tokens"))?;
    let batch = LmBatch::from_tokens(&calib_toks, SEQ, 8);
    let t0 = Instant::now();
    let outs = rt.run_tokens("tinylm_mha_fwd", &batch.inputs, batch.b, batch.t)?;
    let pjrt_ms = t0.elapsed().as_secs_f64() * 1e3;
    println!(
        "PJRT ({}) tinylm_mha_fwd: logits {:?} in {:.1} ms ({:.0} tok/s incl. compile)",
        rt.platform(),
        outs[0].shape(),
        pjrt_ms,
        (batch.b * batch.t) as f64 / (pjrt_ms / 1e3),
    );
    // Steady-state latency (compiled executable is cached).
    let t0 = Instant::now();
    let reps = 5;
    for _ in 0..reps {
        rt.run_tokens("tinylm_mha_fwd", &batch.inputs, batch.b, batch.t)?;
    }
    let steady = t0.elapsed().as_secs_f64() * 1e3 / reps as f64;
    println!(
        "PJRT steady-state: {:.1} ms/batch ({:.0} tok/s)",
        steady,
        (batch.b * batch.t) as f64 / (steady / 1e3)
    );

    // ---- 2. Dense perplexity on the three eval splits.
    let splits = [TextSplit::C4s, TextSplit::Wt2s, TextSplit::Ptbs];
    let mut eval_toks = Vec::new();
    for s in splits {
        eval_toks.push(read_tokens(&art.data(&format!("text_{}.tokens", s.name())))?);
    }
    print!("dense ppl:   ");
    for (s, t) in splits.iter().zip(&eval_toks) {
        print!("{}={:.2}  ", s.name(), lm_perplexity(&model, t, SEQ, 96, 16));
    }
    println!();

    // ---- 3. GRAIL pipeline at 40% structured sparsity (heads + MLP).
    let calib = LmBatch::from_tokens(&calib_toks, SEQ, 128);
    for (label, grail) in [("wanda 40%", false), ("wanda 40% + GRAIL", true)] {
        let mut m = model.clone();
        let cfg = CompressionSpec::uniform(Method::Baseline(Baseline::Wanda), 0.4, grail);
        let t0 = Instant::now();
        let rep = compress_model(&mut m, &calib, &cfg);
        let secs = t0.elapsed().as_secs_f64();
        print!("{label:<22} ");
        for (s, t) in splits.iter().zip(&eval_toks) {
            print!("{}={:.2}  ", s.name(), lm_perplexity(&m, t, SEQ, 96, 16));
        }
        println!(
            "(pipeline {secs:.1}s: calib {:.1}s + comp {:.1}s)",
            rep.calib_seconds, rep.comp_seconds
        );
    }
    Ok(())
}
