"""L1 correctness: Pallas kernels vs the pure-jnp oracles.

Exact-shape pytest cases plus hypothesis sweeps over shapes/values —
the CORE correctness signal for the AOT-exported computations.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.gram import gram, gram_padded
from compile.kernels.linear_act import linear_gelu, linear_gelu_padded
from compile.kernels.matmul import matmul, matmul_padded

RNG = np.random.RandomState(0)


def randf(*shape):
    return RNG.randn(*shape).astype("float32")


# ------------------------------------------------------------------ gram


class TestGram:
    def test_exact_block_shapes(self):
        x = jnp.array(randf(256, 128))
        np.testing.assert_allclose(gram(x), ref.ref_gram(x), rtol=1e-4, atol=1e-3)

    def test_padded_odd_shapes(self):
        x = jnp.array(randf(200, 70))
        np.testing.assert_allclose(gram_padded(x), ref.ref_gram(x), rtol=1e-4, atol=1e-3)

    def test_symmetry_and_psd_diag(self):
        x = jnp.array(randf(100, 33))
        g = np.asarray(gram_padded(x))
        np.testing.assert_allclose(g, g.T, atol=1e-4)
        assert (np.diag(g) >= -1e-5).all()

    def test_rejects_nondivisible(self):
        with pytest.raises(ValueError):
            gram(jnp.zeros((200, 70)))

    @settings(max_examples=25, deadline=None)
    @given(
        n=st.integers(1, 300),
        h=st.integers(1, 160),
        scale=st.floats(0.01, 10.0),
    )
    def test_hypothesis_shapes(self, n, h, scale):
        x = jnp.array(np.random.RandomState(n * 1000 + h).randn(n, h).astype("f4") * scale)
        got = gram_padded(x)
        want = ref.ref_gram(x)
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-2 * scale * scale)


# ---------------------------------------------------------------- matmul


class TestMatmul:
    def test_exact(self):
        a, b = jnp.array(randf(128, 128)), jnp.array(randf(128, 128))
        np.testing.assert_allclose(matmul(a, b), a @ b, rtol=1e-4, atol=1e-3)

    def test_padded(self):
        a, b = jnp.array(randf(33, 47)), jnp.array(randf(47, 21))
        np.testing.assert_allclose(matmul_padded(a, b), a @ b, rtol=1e-4, atol=1e-3)

    def test_inner_dim_mismatch(self):
        with pytest.raises(ValueError):
            matmul(jnp.zeros((4, 5)), jnp.zeros((6, 4)))

    @settings(max_examples=20, deadline=None)
    @given(m=st.integers(1, 150), k=st.integers(1, 150), n=st.integers(1, 150))
    def test_hypothesis_shapes(self, m, k, n):
        r = np.random.RandomState(m * 31 + k * 7 + n)
        a = jnp.array(r.randn(m, k).astype("f4"))
        b = jnp.array(r.randn(k, n).astype("f4"))
        np.testing.assert_allclose(matmul_padded(a, b), a @ b, rtol=1e-3, atol=1e-2)


# ----------------------------------------------------------- linear+gelu


class TestLinearGelu:
    def test_exact(self):
        x, w, b = jnp.array(randf(128, 128)), jnp.array(randf(128, 128)), jnp.array(randf(128))
        np.testing.assert_allclose(
            linear_gelu(x, w, b), ref.ref_linear_gelu(x, w, b), rtol=1e-4, atol=1e-3
        )

    def test_padded(self):
        x, w, b = jnp.array(randf(33, 47)), jnp.array(randf(50, 47)), jnp.array(randf(50))
        np.testing.assert_allclose(
            linear_gelu_padded(x, w, b), ref.ref_linear_gelu(x, w, b), rtol=1e-4, atol=1e-3
        )

    def test_matches_jax_gelu(self):
        # Our tanh constant must match jax.nn.gelu(approximate=True).
        x, w, b = jnp.array(randf(16, 24)), jnp.array(randf(8, 24)), jnp.zeros(8)
        got = linear_gelu_padded(x, w, b)
        want = jax.nn.gelu(x @ w.T, approximate=True)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    @settings(max_examples=20, deadline=None)
    @given(m=st.integers(1, 100), k=st.integers(1, 100), n=st.integers(1, 100))
    def test_hypothesis_shapes(self, m, k, n):
        r = np.random.RandomState(m + 100 * k + 10000 * n)
        x = jnp.array(r.randn(m, k).astype("f4"))
        w = jnp.array(r.randn(n, k).astype("f4"))
        b = jnp.array(r.randn(n).astype("f4"))
        np.testing.assert_allclose(
            linear_gelu_padded(x, w, b), ref.ref_linear_gelu(x, w, b), rtol=1e-3, atol=1e-2
        )


# ----------------------------------------------- ridge oracle (cross-ref)


def test_ridge_reconstruction_identity_gram():
    g = jnp.eye(8)
    keep = jnp.array([1, 4, 6])
    b = ref.ref_ridge_reconstruction(g, keep, 0.0)
    m = np.zeros((8, 3), "f4")
    for col, row in enumerate([1, 4, 6]):
        m[row, col] = 1.0
    np.testing.assert_allclose(b, m, atol=1e-5)
