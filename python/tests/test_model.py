"""L2 correctness: model shapes, kernel/plain-path parity, io round
trips, and (when artifacts exist) trained-checkpoint sanity."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import io_formats, model

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def randf(rng, *shape):
    return jnp.array(rng.randn(*shape).astype("float32"))


class TestShapes:
    def test_mlp(self):
        p = model.init_mlp(jax.random.PRNGKey(0))
        x = randf(np.random.RandomState(0), 4, 768)
        logits, taps = model.mlp_forward(p, x)
        assert logits.shape == (4, 10)
        assert taps[0].shape == (4, 256) and taps[1].shape == (4, 256)

    def test_resnet(self):
        p = model.init_resnet(jax.random.PRNGKey(0))
        x = randf(np.random.RandomState(1), 2, 3, 16, 16)
        logits, taps = model.resnet_forward(p, x)
        assert logits.shape == (2, 10)
        assert len(taps) == 4
        assert taps[0].shape == (2, 32, 16, 16)
        assert taps[2].shape == (2, 64, 8, 8)

    def test_vit(self):
        p = model.init_vit(jax.random.PRNGKey(0))
        x = randf(np.random.RandomState(2), 2, 3, 16, 16)
        logits, taps = model.vit_forward(p, x, model.VIT_CFG)
        assert logits.shape == (2, 10)
        assert len(taps) == 3 and taps[0].shape == (2 * 16, 128)

    @pytest.mark.parametrize("cfg", [model.LM_CFG, model.LM_CFG_GQA])
    def test_lm(self, cfg):
        p = model.init_lm(jax.random.PRNGKey(0), cfg)
        toks = jnp.array(np.random.RandomState(3).randint(0, 64, (2, 16)), jnp.int32)
        logits, taps = model.lm_forward(p, toks, cfg)
        assert logits.shape == (32, 64)
        assert len(taps) == 8
        assert taps[0].shape == (32, 64)  # attn tap: heads*dh
        assert taps[1].shape == (32, 192)  # mlp tap


class TestKernelParity:
    """use_kernels=True (Pallas path) equals the plain-jnp path."""

    def test_vit(self):
        p = model.init_vit(jax.random.PRNGKey(1))
        x = randf(np.random.RandomState(4), 2, 3, 16, 16)
        a, _ = model.vit_forward(p, x, model.VIT_CFG, use_kernels=False)
        b, _ = model.vit_forward(p, x, model.VIT_CFG, use_kernels=True)
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)

    def test_lm(self):
        p = model.init_lm(jax.random.PRNGKey(2))
        toks = jnp.array(np.random.RandomState(5).randint(0, 64, (2, 12)), jnp.int32)
        a, ta = model.lm_forward(p, toks, model.LM_CFG, use_kernels=False)
        b, tb = model.lm_forward(p, toks, model.LM_CFG, use_kernels=True)
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)
        for x, y in zip(ta, tb):
            np.testing.assert_allclose(x, y, rtol=1e-4, atol=1e-4)


class TestGqa:
    def test_gqa_matches_mha_with_duplicated_kv(self):
        cfg = model.LM_CFG_GQA
        p = model.init_lm(jax.random.PRNGKey(3), cfg)
        toks = jnp.array(np.random.RandomState(6).randint(0, 64, (1, 10)), jnp.int32)
        out_g, _ = model.lm_forward(p, toks, cfg)
        # Duplicate each KV head group_size times -> plain MHA.
        dup = dict(p)
        dh = cfg["d_model"] // cfg["n_heads"]
        gs = cfg["n_heads"] // cfg["n_kv"]
        for i in range(cfg["n_layers"]):
            for w in ["wk", "wv"]:
                for suf in ["w", "b"]:
                    key = f"block{i}.attn.{w}.{suf}"
                    arr = p[key]
                    blocks = arr.reshape(cfg["n_kv"], dh, *arr.shape[1:])
                    dup[key] = jnp.repeat(blocks, gs, axis=0).reshape(
                        cfg["n_heads"] * dh, *arr.shape[1:]
                    )
        out_m, _ = model.lm_forward(dup, toks, model.LM_CFG)
        np.testing.assert_allclose(out_g, out_m, rtol=1e-4, atol=1e-4)


class TestIo:
    def test_weights_roundtrip(self, tmp_path):
        p = {"a.w": np.random.randn(3, 4).astype("f4"), "b": np.zeros(7, "f4")}
        path = str(tmp_path / "x.wbin")
        io_formats.write_weights(path, p)
        r = io_formats.read_weights(path)
        assert set(r) == {"a.w", "b"}
        np.testing.assert_array_equal(r["a.w"], p["a.w"])

    def test_weights_reject_garbage(self, tmp_path):
        path = str(tmp_path / "bad.wbin")
        with open(path, "wb") as f:
            f.write(b"nope")
        with pytest.raises(ValueError):
            io_formats.read_weights(path)

    @pytest.mark.skipif(
        not os.path.exists(os.path.join(ART, "data", "vision_train.imgs")),
        reason="artifacts/data not generated",
    )
    def test_reads_rust_generated_data(self):
        x, y, (c, h, w) = io_formats.read_images(os.path.join(ART, "data", "vision_test.imgs"))
        assert (c, h, w) == (3, 16, 16)
        assert x.shape[0] == y.shape[0] > 0
        assert np.isfinite(x).all()
        toks, vocab = io_formats.read_tokens(os.path.join(ART, "data", "text_c4s.tokens"))
        assert vocab == 64
        assert toks.max() < 64


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "checkpoints", "tinylm_mha.wbin")),
    reason="checkpoints not trained",
)
class TestTrainedCheckpoints:
    def test_vision_checkpoints_beat_chance(self):
        x, y, _ = io_formats.read_images(os.path.join(ART, "data", "vision_test.imgs"))
        x4 = jnp.array(x[:256].reshape(-1, 3, 16, 16))
        yy = y[:256]
        p = {k: jnp.array(v) for k, v in io_formats.read_weights(
            os.path.join(ART, "checkpoints", "resnet_seed0.wbin")).items()}
        logits, _ = model.resnet_forward(p, x4)
        acc = float((np.asarray(logits).argmax(-1) == yy).mean())
        assert acc > 0.7, acc

    def test_lm_checkpoint_beats_uniform(self):
        toks, _ = io_formats.read_tokens(os.path.join(ART, "data", "text_c4s.tokens"))
        p = {k: jnp.array(v) for k, v in io_formats.read_weights(
            os.path.join(ART, "checkpoints", "tinylm_mha.wbin")).items()}
        seq = 32
        inp = jnp.array(toks[: 8 * seq].reshape(8, seq).astype("i4"))
        tgt = toks[1 : 8 * seq + 1].reshape(8, seq)
        logits, _ = model.lm_forward(p, inp, model.LM_CFG)
        ls = jax.nn.log_softmax(logits)
        nll = -np.asarray(ls)[np.arange(8 * seq), tgt.reshape(-1)].mean()
        assert np.exp(nll) < 30.0, np.exp(nll)
