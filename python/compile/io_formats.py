"""Binary interchange with the Rust coordinator.

Rust is the source of truth for data (GRTK tokens / GRIM images, written
by `grail datagen`); Python reads them for build-time training and
writes checkpoints back as GRWB weight bundles. Layouts are documented
in `rust/src/data/io.rs` and `rust/src/nn/weights.rs`.
"""

from __future__ import annotations

import struct

import numpy as np

MAGIC_TOKENS = 0x4752544B  # "GRTK"
MAGIC_IMAGES = 0x4752494D  # "GRIM"
MAGIC_WEIGHTS = 0x47525742  # "GRWB"
WEIGHTS_VERSION = 1


def read_tokens(path: str) -> tuple[np.ndarray, int]:
    """Read a GRTK token stream -> (tokens u16[N], vocab)."""
    with open(path, "rb") as f:
        magic, vocab = struct.unpack("<II", f.read(8))
        if magic != MAGIC_TOKENS:
            raise ValueError(f"{path}: not a GRTK file")
        (n,) = struct.unpack("<Q", f.read(8))
        tokens = np.frombuffer(f.read(2 * n), dtype="<u2")
        if tokens.size != n:
            raise ValueError(f"{path}: truncated")
    return tokens.copy(), vocab


def read_images(path: str) -> tuple[np.ndarray, np.ndarray, tuple[int, int, int]]:
    """Read a GRIM image set -> (x f32[N, C*H*W], y u16[N], (c, h, w))."""
    with open(path, "rb") as f:
        magic, n, c, h, w = struct.unpack("<IIIII", f.read(20))
        if magic != MAGIC_IMAGES:
            raise ValueError(f"{path}: not a GRIM file")
        d = c * h * w
        x = np.frombuffer(f.read(4 * n * d), dtype="<f4").reshape(n, d)
        y = np.frombuffer(f.read(2 * n), dtype="<u2")
        if x.shape[0] != n or y.size != n:
            raise ValueError(f"{path}: truncated")
    return x.copy(), y.copy(), (c, h, w)


def write_weights(path: str, tensors: dict[str, np.ndarray]) -> None:
    """Write a GRWB weight bundle (sorted by name, f32)."""
    with open(path, "wb") as f:
        f.write(struct.pack("<III", MAGIC_WEIGHTS, WEIGHTS_VERSION, len(tensors)))
        for name in sorted(tensors):
            arr = np.ascontiguousarray(tensors[name], dtype="<f4")
            nb = name.encode("utf-8")
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<I", arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            f.write(arr.tobytes())


def read_weights(path: str) -> dict[str, np.ndarray]:
    """Read a GRWB weight bundle -> {name: f32 array}."""
    out: dict[str, np.ndarray] = {}
    with open(path, "rb") as f:
        header = f.read(12)
        if len(header) < 12:
            raise ValueError(f"{path}: truncated GRWB header")
        magic, version, count = struct.unpack("<III", header)
        if magic != MAGIC_WEIGHTS:
            raise ValueError(f"{path}: not a GRWB file")
        if version != WEIGHTS_VERSION:
            raise ValueError(f"{path}: unsupported version {version}")
        for _ in range(count):
            (name_len,) = struct.unpack("<I", f.read(4))
            name = f.read(name_len).decode("utf-8")
            (ndim,) = struct.unpack("<I", f.read(4))
            shape = struct.unpack(f"<{ndim}I", f.read(4 * ndim))
            size = int(np.prod(shape)) if ndim else 1
            arr = np.frombuffer(f.read(4 * size), dtype="<f4").reshape(shape)
            out[name] = arr.copy()
    return out
