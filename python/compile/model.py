"""L2: the model forward graphs in JAX.

These mirror the Rust `nn::models` *exactly* (same parameter names, same
tanh-GELU, same layer-norm epsilon, same attention layout) — the
`rust/tests/runtime_pjrt.rs` integration test loads a checkpoint into
both implementations and asserts elementwise agreement.

Parameters are flat `{name: array}` dicts using the GRWB names. The
`use_kernels` flag routes dense hot spots through the L1 Pallas kernels
(used for the AOT-exported graphs); training uses the plain-jnp path
for speed (both are pytest-verified equal).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.linear_act import linear_gelu_padded

NORM_EPS = 1e-5
_GELU_C = 0.7978845608028654


def gelu(x):
    """tanh-approximate GELU (matches Rust `nn::gelu_scalar`)."""
    return 0.5 * x * (1.0 + jnp.tanh(_GELU_C * (x + 0.044715 * x**3)))


def layernorm(x, gamma, beta):
    """LayerNorm over the last axis with the shared epsilon."""
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + NORM_EPS) * gamma + beta


def linear(x, w, b):
    """`x Wᵀ + b` with `w: [out, in]`."""
    return x @ w.T + b


def batchnorm_eval(x, gamma, beta, mean, var):
    """Eval-mode BN on `[n, c, h, w]`."""
    g = gamma.reshape(1, -1, 1, 1)
    b = beta.reshape(1, -1, 1, 1)
    m = mean.reshape(1, -1, 1, 1)
    v = var.reshape(1, -1, 1, 1)
    return (x - m) / jnp.sqrt(v + NORM_EPS) * g + b


# ------------------------------------------------------------------ MLP


def mlp_forward(params, x, use_kernels: bool = False):
    """`relu(fc1) -> relu(fc2) -> head`; returns (logits, [h1, h2])."""
    h1 = jax.nn.relu(linear(x, params["fc1.w"], params["fc1.b"]))
    h2 = jax.nn.relu(linear(h1, params["fc2.w"], params["fc2.b"]))
    del use_kernels  # ReLU MLP keeps the plain path; kernels cover GELU blocks
    return linear(h2, params["head.w"], params["head.b"]), [h1, h2]


# ------------------------------------------------------------ MiniResNet


def conv2d(x, w, b, stride: int, pad: int):
    """NCHW conv matching Rust `Conv2d::forward`."""
    y = jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding=((pad, pad), (pad, pad)),
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    return y + b.reshape(1, -1, 1, 1)


def _bn(params, name, x):
    return batchnorm_eval(
        x,
        params[f"{name}.gamma"],
        params[f"{name}.beta"],
        params[f"{name}.mean"],
        params[f"{name}.var"],
    )


def resnet_forward(params, x, n_blocks: int = 4):
    """MiniResNet eval forward on `[n, 3, 16, 16]`; returns
    (logits, [mid taps as [n, c, oh, ow]])."""
    cur = jax.nn.relu(_bn(params, "stem.bn", conv2d(x, params["stem.conv.w"], params["stem.conv.b"], 1, 1)))
    taps = []
    for i in range(n_blocks):
        p = f"block{i}"
        has_down = f"{p}.down.conv.w" in params
        stride = 2 if has_down else 1
        mid = jax.nn.relu(
            _bn(params, f"{p}.bn1", conv2d(cur, params[f"{p}.conv1.w"], params[f"{p}.conv1.b"], stride, 1))
        )
        taps.append(mid)
        out = _bn(params, f"{p}.bn2", conv2d(mid, params[f"{p}.conv2.w"], params[f"{p}.conv2.b"], 1, 1))
        if has_down:
            skip = _bn(params, f"{p}.down.bn", conv2d(cur, params[f"{p}.down.conv.w"], params[f"{p}.down.conv.b"], stride, 0))
        else:
            skip = cur
        cur = jax.nn.relu(out + skip)
    pooled = cur.mean(axis=(2, 3))
    return linear(pooled, params["head.w"], params["head.b"]), taps


# ------------------------------------------------------------- attention


def attention(params, prefix, x, b, t, n_heads, n_kv, d_head, causal):
    """Multi-head attention on `[b*t, d]` rows; returns (out, tap)."""
    q = linear(x, params[f"{prefix}.wq.w"], params[f"{prefix}.wq.b"])
    k = linear(x, params[f"{prefix}.wk.w"], params[f"{prefix}.wk.b"])
    v = linear(x, params[f"{prefix}.wv.w"], params[f"{prefix}.wv.b"])
    q = q.reshape(b, t, n_heads, d_head)
    k = k.reshape(b, t, n_kv, d_head)
    v = v.reshape(b, t, n_kv, d_head)
    gs = n_heads // n_kv
    if gs > 1:
        k = jnp.repeat(k, gs, axis=2)
        v = jnp.repeat(v, gs, axis=2)
    scale = 1.0 / jnp.sqrt(jnp.float32(d_head))
    scores = jnp.einsum("bthd,bshd->bhts", q, k) * scale
    if causal:
        mask = jnp.tril(jnp.ones((t, t), dtype=bool))
        scores = jnp.where(mask[None, None], scores, -jnp.inf)
    attn = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhts,bshd->bthd", attn, v)  # [b, t, H, dh]
    tap = ctx.reshape(b * t, n_heads * d_head)
    out = linear(tap, params[f"{prefix}.wo.w"], params[f"{prefix}.wo.b"])
    return out, tap


# --------------------------------------------------------------- TinyViT


def patchify(x, patch: int):
    """`[n, c, h, w] -> [n*tokens, c*p*p]` with (c, dy, dx) feature
    order and row-major tokens (matches Rust `TinyViT::patchify`)."""
    n, c, h, w = x.shape
    gh, gw = h // patch, w // patch
    x = x.reshape(n, c, gh, patch, gw, patch)
    x = x.transpose(0, 2, 4, 1, 3, 5)  # n, gh, gw, c, dy, dx
    return x.reshape(n * gh * gw, c * patch * patch)


def vit_forward(params, x, cfg, use_kernels: bool = False):
    """TinyViT forward on `[n, 3, 16, 16]`; returns (logits, [mlp taps])."""
    n = x.shape[0]
    patch, d, n_heads, n_layers = cfg["patch"], cfg["d_model"], cfg["n_heads"], cfg["n_layers"]
    t = (x.shape[2] // patch) * (x.shape[3] // patch)
    dh = d // n_heads
    cur = linear(patchify(x, patch), params["patch.w"], params["patch.b"])
    cur = cur + jnp.tile(params["pos"], (n, 1))
    taps = []
    for i in range(n_layers):
        p = f"block{i}"
        normed = layernorm(cur, params[f"{p}.ln1.gamma"], params[f"{p}.ln1.beta"])
        attn_out, _ = attention(params, f"{p}.attn", normed, n, t, n_heads, n_heads, dh, False)
        cur = cur + attn_out
        normed = layernorm(cur, params[f"{p}.ln2.gamma"], params[f"{p}.ln2.beta"])
        if use_kernels:
            hid = linear_gelu_padded(normed, params[f"{p}.fc.w"], params[f"{p}.fc.b"])
        else:
            hid = gelu(linear(normed, params[f"{p}.fc.w"], params[f"{p}.fc.b"]))
        taps.append(hid)
        cur = cur + linear(hid, params[f"{p}.proj.w"], params[f"{p}.proj.b"])
    normed = layernorm(cur, params["ln_f.gamma"], params["ln_f.beta"])
    pooled = normed.reshape(n, t, d).mean(axis=1)
    return linear(pooled, params["head.w"], params["head.b"]), taps


# ---------------------------------------------------------------- TinyLm


def lm_forward(params, tokens, cfg, use_kernels: bool = False):
    """TinyLm forward on token ids `[b, t]`; returns
    (logits [b*t, vocab], taps [attn0, mlp0, attn1, ...])."""
    b, t = tokens.shape
    d = cfg["d_model"]
    n_heads, n_kv, n_layers = cfg["n_heads"], cfg["n_kv"], cfg["n_layers"]
    dh = d // n_heads
    emb = params["embed"][tokens.reshape(-1)]  # [b*t, d]
    pos = jnp.tile(params["pos"][:t], (b, 1))
    cur = emb + pos
    taps = []
    for i in range(n_layers):
        p = f"block{i}"
        normed = layernorm(cur, params[f"{p}.ln1.gamma"], params[f"{p}.ln1.beta"])
        attn_out, tap = attention(params, f"{p}.attn", normed, b, t, n_heads, n_kv, dh, True)
        taps.append(tap)
        cur = cur + attn_out
        normed = layernorm(cur, params[f"{p}.ln2.gamma"], params[f"{p}.ln2.beta"])
        if use_kernels:
            hid = linear_gelu_padded(normed, params[f"{p}.fc.w"], params[f"{p}.fc.b"])
        else:
            hid = gelu(linear(normed, params[f"{p}.fc.w"], params[f"{p}.fc.b"]))
        taps.append(hid)
        cur = cur + linear(hid, params[f"{p}.proj.w"], params[f"{p}.proj.b"])
    normed = layernorm(cur, params["ln_f.gamma"], params["ln_f.beta"])
    return linear(normed, params["lm_head.w"], params["lm_head.b"]), taps


# -------------------------------------------------------- initialization


def _he(key, out_dim, in_dim):
    std = (2.0 / in_dim) ** 0.5
    return jax.random.normal(key, (out_dim, in_dim), jnp.float32) * std


def init_mlp(key, in_dim=768, hidden=256, classes=10):
    """Random MLP parameters (GRWB names)."""
    ks = jax.random.split(key, 3)
    p = {}
    for k, name, (o, i) in zip(
        ks, ["fc1", "fc2", "head"], [(hidden, in_dim), (hidden, hidden), (classes, hidden)]
    ):
        p[f"{name}.w"] = _he(k, o, i)
        p[f"{name}.b"] = jnp.zeros((o,), jnp.float32)
    return p


def _conv_init(key, o, c, kh, kw):
    std = (2.0 / (c * kh * kw)) ** 0.5
    return jax.random.normal(key, (o, c, kh, kw), jnp.float32) * std


def _bn_init(c):
    return {
        "gamma": jnp.ones((c,), jnp.float32),
        "beta": jnp.zeros((c,), jnp.float32),
        "mean": jnp.zeros((c,), jnp.float32),
        "var": jnp.ones((c,), jnp.float32),
    }


def init_resnet(key, widths=(32, 64), classes=10):
    """Random MiniResNet parameters (stem + 4 blocks, paper topology)."""
    w1, w2 = widths
    keys = iter(jax.random.split(key, 16))
    p = {"stem.conv.w": _conv_init(next(keys), w1, 3, 3, 3), "stem.conv.b": jnp.zeros((w1,))}
    for k, v in _bn_init(w1).items():
        p[f"stem.bn.{k}"] = v
    specs = [(w1, w1, False), (w1, w1, False), (w1, w2, True), (w2, w2, False)]
    for i, (cin, cout, down) in enumerate(specs):
        p[f"block{i}.conv1.w"] = _conv_init(next(keys), cout, cin, 3, 3)
        p[f"block{i}.conv1.b"] = jnp.zeros((cout,))
        p[f"block{i}.conv2.w"] = _conv_init(next(keys), cout, cout, 3, 3)
        p[f"block{i}.conv2.b"] = jnp.zeros((cout,))
        for k, v in _bn_init(cout).items():
            p[f"block{i}.bn1.{k}"] = v
            p[f"block{i}.bn2.{k}"] = v
        if down:
            p[f"block{i}.down.conv.w"] = _conv_init(next(keys), cout, cin, 1, 1)
            p[f"block{i}.down.conv.b"] = jnp.zeros((cout,))
            for k, v in _bn_init(cout).items():
                p[f"block{i}.down.bn.{k}"] = v
    p["head.w"] = _he(next(keys), classes, w2)
    p["head.b"] = jnp.zeros((classes,))
    return p


VIT_CFG = {"patch": 4, "d_model": 64, "n_heads": 4, "d_ff": 128, "n_layers": 3, "classes": 10}
LM_CFG = {"vocab": 64, "d_model": 64, "n_heads": 8, "n_kv": 8, "d_ff": 192, "n_layers": 4, "max_seq": 64}
LM_CFG_GQA = dict(LM_CFG, n_kv=4)


def _attn_init(keys, d, n_heads, n_kv, dh, prefix, p):
    p[f"{prefix}.wq.w"] = _he(next(keys), n_heads * dh, d)
    p[f"{prefix}.wq.b"] = jnp.zeros((n_heads * dh,))
    p[f"{prefix}.wk.w"] = _he(next(keys), n_kv * dh, d)
    p[f"{prefix}.wk.b"] = jnp.zeros((n_kv * dh,))
    p[f"{prefix}.wv.w"] = _he(next(keys), n_kv * dh, d)
    p[f"{prefix}.wv.b"] = jnp.zeros((n_kv * dh,))
    p[f"{prefix}.wo.w"] = _he(next(keys), d, n_heads * dh)
    p[f"{prefix}.wo.b"] = jnp.zeros((d,))


def _ln_init(prefix, d, p):
    p[f"{prefix}.gamma"] = jnp.ones((d,), jnp.float32)
    p[f"{prefix}.beta"] = jnp.zeros((d,), jnp.float32)


def init_vit(key, cfg=None):
    """Random TinyViT parameters."""
    cfg = cfg or VIT_CFG
    d, n_layers = cfg["d_model"], cfg["n_layers"]
    dh = d // cfg["n_heads"]
    tokens = (16 // cfg["patch"]) ** 2
    keys = iter(jax.random.split(key, 8 * n_layers + 4))
    p = {
        "patch.w": _he(next(keys), d, 3 * cfg["patch"] ** 2),
        "patch.b": jnp.zeros((d,)),
        "pos": jax.random.normal(next(keys), (tokens, d), jnp.float32) * 0.02,
    }
    for i in range(n_layers):
        _ln_init(f"block{i}.ln1", d, p)
        _attn_init(keys, d, cfg["n_heads"], cfg["n_heads"], dh, f"block{i}.attn", p)
        _ln_init(f"block{i}.ln2", d, p)
        p[f"block{i}.fc.w"] = _he(next(keys), cfg["d_ff"], d)
        p[f"block{i}.fc.b"] = jnp.zeros((cfg["d_ff"],))
        p[f"block{i}.proj.w"] = _he(next(keys), d, cfg["d_ff"])
        p[f"block{i}.proj.b"] = jnp.zeros((d,))
    _ln_init("ln_f", d, p)
    p["head.w"] = _he(next(keys), cfg["classes"], d)
    p["head.b"] = jnp.zeros((cfg["classes"],))
    return p


def init_lm(key, cfg=None):
    """Random TinyLm parameters."""
    cfg = cfg or LM_CFG
    d, n_layers = cfg["d_model"], cfg["n_layers"]
    dh = d // cfg["n_heads"]
    keys = iter(jax.random.split(key, 8 * n_layers + 6))
    p = {
        "embed": jax.random.normal(next(keys), (cfg["vocab"], d), jnp.float32) * 0.05,
        "pos": jax.random.normal(next(keys), (cfg["max_seq"], d), jnp.float32) * 0.02,
    }
    for i in range(n_layers):
        _ln_init(f"block{i}.ln1", d, p)
        _attn_init(keys, d, cfg["n_heads"], cfg["n_kv"], dh, f"block{i}.attn", p)
        _ln_init(f"block{i}.ln2", d, p)
        p[f"block{i}.fc.w"] = _he(next(keys), cfg["d_ff"], d)
        p[f"block{i}.fc.b"] = jnp.zeros((cfg["d_ff"],))
        p[f"block{i}.proj.w"] = _he(next(keys), d, cfg["d_ff"])
        p[f"block{i}.proj.b"] = jnp.zeros((d,))
    _ln_init("ln_f", d, p)
    p["lm_head.w"] = _he(next(keys), cfg["vocab"], d)
    p["lm_head.b"] = jnp.zeros((cfg["vocab"],))
    return p
