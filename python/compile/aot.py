"""AOT export: lower the L2/L1 graphs to HLO *text* artifacts and train
the checkpoint zoo.

HLO text (NOT `lowered.compiler_ir("hlo").as_hlo_text()` via serialized
protos) is the interchange format: jax ≥ 0.5 emits HloModuleProto with
64-bit instruction ids that the image's xla_extension 0.5.1 rejects;
the text parser reassigns ids (see /opt/xla-example/README.md).

Run once by `make artifacts`:

    cd python && python -m compile.aot --out ../artifacts [--quick]

Inputs (written earlier in the Makefile by `grail datagen`):
    artifacts/data/*.imgs, *.tokens
Outputs:
    artifacts/checkpoints/*.wbin      trained weights (GRWB)
    artifacts/hlo/*.hlo.txt           PJRT-loadable computations
    artifacts/MANIFEST.txt            inventory + training metrics
"""

from __future__ import annotations

import argparse
import functools
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import io_formats, model, train
from .kernels.gram import gram_padded

# Gram widths the coordinator needs: LM attention feat (64), TinyViT
# MLP (128), TinyLm MLP (192), MLP hidden (256).
GRAM_WIDTHS = (64, 128, 192, 256)
GRAM_ROWS = 1024


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def export_gram_kernels(hlo_dir, log):
    """One Gram-accumulation computation per calibration width."""
    for h in GRAM_WIDTHS:
        def fn(x):
            return (gram_padded(x),)

        spec = jax.ShapeDtypeStruct((GRAM_ROWS, h), jnp.float32)
        text = to_hlo_text(jax.jit(fn).lower(spec))
        path = os.path.join(hlo_dir, f"gram_h{h}_n{GRAM_ROWS}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        log(f"  wrote {path} ({len(text)} chars)")


def _load_ckpt(ckpt_dir, name):
    return {
        k: jnp.array(v)
        for k, v in io_formats.read_weights(os.path.join(ckpt_dir, f"{name}.wbin")).items()
    }


def export_model_forwards(ckpt_dir, hlo_dir, log):
    """Full-width eval forwards with weights baked as constants — the
    fixed-shape hot path the Rust runtime executes via PJRT."""
    exports = []

    p = _load_ckpt(ckpt_dir, "mlp_seed0")
    exports.append(
        (
            "mlp_seed0_fwd",
            functools.partial(lambda params, x: (model.mlp_forward(params, x)[0],), p),
            [jax.ShapeDtypeStruct((128, 768), jnp.float32)],
        )
    )

    p = _load_ckpt(ckpt_dir, "resnet_seed0")
    exports.append(
        (
            "resnet_seed0_fwd",
            functools.partial(lambda params, x: (model.resnet_forward(params, x)[0],), p),
            [jax.ShapeDtypeStruct((64, 3, 16, 16), jnp.float32)],
        )
    )

    p = _load_ckpt(ckpt_dir, "vit_seed0")
    exports.append(
        (
            "vit_seed0_fwd",
            functools.partial(
                lambda params, x: (model.vit_forward(params, x, model.VIT_CFG, use_kernels=True)[0],),
                p,
            ),
            [jax.ShapeDtypeStruct((64, 3, 16, 16), jnp.float32)],
        )
    )

    for tag, cfg in [("mha", model.LM_CFG), ("gqa", model.LM_CFG_GQA)]:
        p = _load_ckpt(ckpt_dir, f"tinylm_{tag}")
        exports.append(
            (
                f"tinylm_{tag}_fwd",
                functools.partial(
                    lambda params, c, toks: (model.lm_forward(params, toks, c, use_kernels=True)[0],),
                    p,
                    cfg,
                ),
                [jax.ShapeDtypeStruct((8, 32), jnp.int32)],
            )
        )

    # Calibration variant: logits + every consumer-input tap, so the
    # runtime can drive Gram accumulation from a single PJRT call.
    p = _load_ckpt(ckpt_dir, "tinylm_mha")
    def lm_calib(toks, params=p):
        logits, taps = model.lm_forward(params, toks, model.LM_CFG, use_kernels=True)
        return tuple([logits] + taps)

    exports.append(("tinylm_mha_calib", lm_calib, [jax.ShapeDtypeStruct((8, 32), jnp.int32)]))

    for name, fn, specs in exports:
        text = to_hlo_text(jax.jit(fn).lower(*specs))
        path = os.path.join(hlo_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        log(f"  wrote {path} ({len(text)} chars)")
    return [e[0] for e in exports]


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifacts directory")
    ap.add_argument("--quick", action="store_true", help="reduced training (smoke runs)")
    ap.add_argument(
        "--retrain",
        action="store_true",
        help="retrain even when checkpoints already exist (default: reuse)",
    )
    args = ap.parse_args(argv)

    out = os.path.abspath(args.out)
    data_dir = os.path.join(out, "data")
    ckpt_dir = os.path.join(out, "checkpoints")
    hlo_dir = os.path.join(out, "hlo")
    if not os.path.exists(os.path.join(data_dir, "vision_train.imgs")):
        sys.exit(
            f"missing {data_dir}/vision_train.imgs — run `cargo run --release "
            "--bin grail -- datagen` first (the Makefile `artifacts` target does this)"
        )
    os.makedirs(ckpt_dir, exist_ok=True)
    os.makedirs(hlo_dir, exist_ok=True)

    log = print
    summary = {}
    if not args.retrain and os.path.exists(os.path.join(ckpt_dir, "tinylm_mha.wbin")):
        log("checkpoints exist, reusing (pass --retrain to force)")
    else:
        log("training checkpoint zoo (this is the slow step)...")
        summary = train.train_zoo(data_dir, ckpt_dir, log=log, quick=args.quick)

    log("exporting gram kernels...")
    export_gram_kernels(hlo_dir, log)
    log("exporting model forwards...")
    names = export_model_forwards(ckpt_dir, hlo_dir, log)

    with open(os.path.join(out, "MANIFEST.txt"), "w") as f:
        f.write("# GRAIL artifacts manifest\n")
        for k, v in sorted(summary.items()):
            f.write(f"ckpt {k} metric {v:.4f}\n")
        for h in GRAM_WIDTHS:
            f.write(f"hlo gram_h{h}_n{GRAM_ROWS}\n")
        for n in names:
            f.write(f"hlo {n}\n")
    log("aot export complete")


if __name__ == "__main__":
    main()
