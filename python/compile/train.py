"""Build-time checkpoint training (the repro substitute for the paper's
pretrained zoos — DESIGN.md §2).

Runs once inside `make artifacts`; Python never executes at request
time. Data comes from the Rust-generated binaries under
`artifacts/data/` so both languages see identical distributions.
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
import numpy as np

from . import io_formats, model


def _sgd_momentum(params, grads, vel, lr, mu=0.9):
    new_vel = {k: mu * vel[k] + grads[k] for k in grads}
    new_params = dict(params)
    for k in grads:
        new_params[k] = params[k] - lr * new_vel[k]
    return new_params, new_vel


def _xent(logits, labels):
    ls = jax.nn.log_softmax(logits)
    return -ls[jnp.arange(labels.shape[0]), labels].mean()


def _batches(n, bs, steps, seed):
    rng = np.random.RandomState(seed)
    for _ in range(steps):
        yield rng.randint(0, n, size=bs)


# ------------------------------------------------------------------ MLP


def train_mlp(key, x, y, steps=300, bs=64, lr=0.05, log=None):
    """Train an MLP classifier; returns (params, final train acc)."""
    params = model.init_mlp(key)

    @jax.jit
    def step(params, vel, xb, yb, lr):
        def loss_fn(p):
            logits, _ = model.mlp_forward(p, xb)
            return _xent(logits, yb)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, vel = _sgd_momentum(params, grads, vel, lr)
        return params, vel, loss

    vel = {k: jnp.zeros_like(v) for k, v in params.items()}
    for i, idx in enumerate(_batches(x.shape[0], bs, steps, 0)):
        lr_t = lr * (0.1 if i > steps * 0.8 else 1.0)
        params, vel, loss = step(params, vel, x[idx], y[idx], lr_t)
        if log and i % 100 == 0:
            log(f"  mlp step {i}: loss {float(loss):.4f}")
    logits, _ = model.mlp_forward(params, x[:512])
    acc = float((logits.argmax(-1) == y[:512]).mean())
    return params, acc


# ------------------------------------------------------------ MiniResNet

_BN_KEYS = ("mean", "var")


def _resnet_forward_train(params, x, n_blocks=4):
    """Training-mode forward: BN uses batch statistics; returns
    (logits, {bn_name: (batch_mean, batch_var)})."""
    stats = {}

    def bn_train(name, h):
        mu = h.mean(axis=(0, 2, 3))
        var = h.var(axis=(0, 2, 3))
        stats[name] = (mu, var)
        g = params[f"{name}.gamma"].reshape(1, -1, 1, 1)
        b = params[f"{name}.beta"].reshape(1, -1, 1, 1)
        return (h - mu.reshape(1, -1, 1, 1)) / jnp.sqrt(
            var.reshape(1, -1, 1, 1) + model.NORM_EPS
        ) * g + b

    cur = jax.nn.relu(bn_train("stem.bn", model.conv2d(x, params["stem.conv.w"], params["stem.conv.b"], 1, 1)))
    for i in range(n_blocks):
        p = f"block{i}"
        has_down = f"{p}.down.conv.w" in params
        stride = 2 if has_down else 1
        mid = jax.nn.relu(bn_train(f"{p}.bn1", model.conv2d(cur, params[f"{p}.conv1.w"], params[f"{p}.conv1.b"], stride, 1)))
        out = bn_train(f"{p}.bn2", model.conv2d(mid, params[f"{p}.conv2.w"], params[f"{p}.conv2.b"], 1, 1))
        if has_down:
            skip = bn_train(f"{p}.down.bn", model.conv2d(cur, params[f"{p}.down.conv.w"], params[f"{p}.down.conv.b"], stride, 0))
        else:
            skip = cur
        cur = jax.nn.relu(out + skip)
    pooled = cur.mean(axis=(2, 3))
    return model.linear(pooled, params["head.w"], params["head.b"]), stats


def train_resnet(key, x, y, steps=400, bs=64, lr=0.05, log=None):
    """Train MiniResNet (tracking BN running stats); `x: [n, 3, 16, 16]`."""
    params = model.init_resnet(key)
    trainable = [k for k in params if not k.endswith((".mean", ".var"))]

    @jax.jit
    def step(params, vel, xb, yb, lr):
        def loss_fn(tp):
            p = dict(params)
            p.update(tp)
            logits, stats = _resnet_forward_train(p, xb)
            return _xent(logits, yb), stats

        tp = {k: params[k] for k in trainable}
        (loss, stats), grads = jax.value_and_grad(loss_fn, has_aux=True)(tp)
        new_tp, vel = _sgd_momentum(tp, grads, vel, lr)
        new_params = dict(params)
        new_params.update(new_tp)
        # Running-stat EMA (momentum 0.1, eval-mode convention).
        for name, (mu, var) in stats.items():
            new_params[f"{name}.mean"] = 0.9 * params[f"{name}.mean"] + 0.1 * mu
            new_params[f"{name}.var"] = 0.9 * params[f"{name}.var"] + 0.1 * var
        return new_params, vel, loss

    vel = {k: jnp.zeros_like(params[k]) for k in trainable}
    for i, idx in enumerate(_batches(x.shape[0], bs, steps, 1)):
        lr_t = lr * (0.1 if i > steps * 0.8 else 1.0)
        params, vel, loss = step(params, vel, x[idx], y[idx], lr_t)
        if log and i % 100 == 0:
            log(f"  resnet step {i}: loss {float(loss):.4f}")
    logits, _ = model.resnet_forward(params, x[:512])
    acc = float((logits.argmax(-1) == y[:512]).mean())
    return params, acc


# --------------------------------------------------------------- TinyViT


def train_vit(key, x, y, steps=500, bs=64, lr=0.02, log=None):
    """Train TinyViT; `x: [n, 3, 16, 16]`."""
    params = model.init_vit(key)

    @jax.jit
    def step(params, vel, xb, yb, lr):
        def loss_fn(p):
            logits, _ = model.vit_forward(p, xb, model.VIT_CFG)
            return _xent(logits, yb)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, vel = _sgd_momentum(params, grads, vel, lr)
        return params, vel, loss

    vel = {k: jnp.zeros_like(v) for k, v in params.items()}
    for i, idx in enumerate(_batches(x.shape[0], bs, steps, 2)):
        lr_t = lr * min(1.0, (i + 1) / 50) * (0.1 if i > steps * 0.8 else 1.0)
        params, vel, loss = step(params, vel, x[idx], y[idx], lr_t)
        if log and i % 100 == 0:
            log(f"  vit step {i}: loss {float(loss):.4f}")
    logits, _ = model.vit_forward(params, x[:512], model.VIT_CFG)
    acc = float((logits.argmax(-1) == y[:512]).mean())
    return params, acc


# ---------------------------------------------------------------- TinyLm


def train_lm(key, tokens, cfg, steps=800, bs=16, seq=32, lr=0.05, log=None):
    """Train TinyLm on a token stream; returns (params, train ppl)."""
    params = model.init_lm(key, cfg)

    n_windows = (tokens.shape[0] - 1) // seq
    inputs = tokens[: n_windows * seq].reshape(n_windows, seq)
    targets = tokens[1 : n_windows * seq + 1].reshape(n_windows, seq)

    @jax.jit
    def step(params, vel, xb, yb, lr):
        def loss_fn(p):
            logits, _ = model.lm_forward(p, xb, cfg)
            return _xent(logits, yb.reshape(-1))

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, vel = _sgd_momentum(params, grads, vel, lr)
        return params, vel, loss

    vel = {k: jnp.zeros_like(v) for k, v in params.items()}
    loss = jnp.inf
    for i, idx in enumerate(_batches(n_windows, bs, steps, 3)):
        lr_t = lr * min(1.0, (i + 1) / 80) * (0.1 if i > steps * 0.8 else 1.0)
        params, vel, loss = step(params, vel, inputs[idx], targets[idx], lr_t)
        if log and i % 200 == 0:
            log(f"  lm step {i}: loss {float(loss):.4f}")
    return params, float(jnp.exp(loss))


# ------------------------------------------------------------- top level


def load_vision(data_dir):
    """Load the Rust-generated vision splits as NCHW arrays."""
    x, y, (c, h, w) = io_formats.read_images(os.path.join(data_dir, "vision_train.imgs"))
    return jnp.array(x.reshape(-1, c, h, w)), jnp.array(y.astype("i4"))


def load_text(data_dir):
    """Load the Rust-generated training token stream."""
    tokens, _vocab = io_formats.read_tokens(os.path.join(data_dir, "text_train.tokens"))
    return jnp.array(tokens.astype("i4"))


def train_zoo(data_dir, out_dir, log=print, quick=False):
    """Train every checkpoint the experiments need and write GRWB
    bundles. `quick=True` trims steps for CI-style smoke runs."""
    os.makedirs(out_dir, exist_ok=True)
    xv, yv = load_vision(data_dir)
    toks = load_text(data_dir)
    scale = 0.25 if quick else 1.0
    summary = {}

    for seed in range(2 if quick else 3):
        params, acc = train_mlp(
            jax.random.PRNGKey(100 + seed), xv.reshape(xv.shape[0], -1), yv,
            steps=int(400 * scale), log=log,
        )
        name = f"mlp_seed{seed}"
        io_formats.write_weights(os.path.join(out_dir, f"{name}.wbin"), _np(params))
        summary[name] = acc
        log(f"{name}: train acc {acc:.3f}")

    for seed in range(2 if quick else 4):
        params, acc = train_resnet(
            jax.random.PRNGKey(200 + seed), xv, yv, steps=int(500 * scale), log=log
        )
        name = f"resnet_seed{seed}"
        io_formats.write_weights(os.path.join(out_dir, f"{name}.wbin"), _np(params))
        summary[name] = acc
        log(f"{name}: train acc {acc:.3f}")

    for seed in range(2 if quick else 3):
        params, acc = train_vit(
            jax.random.PRNGKey(300 + seed), xv, yv, steps=int(600 * scale), log=log
        )
        name = f"vit_seed{seed}"
        io_formats.write_weights(os.path.join(out_dir, f"{name}.wbin"), _np(params))
        summary[name] = acc
        log(f"{name}: train acc {acc:.3f}")

    for tag, cfg in [("mha", model.LM_CFG), ("gqa", model.LM_CFG_GQA)]:
        params, ppl = train_lm(
            jax.random.PRNGKey(400), toks, cfg, steps=int(900 * scale), log=log
        )
        name = f"tinylm_{tag}"
        io_formats.write_weights(os.path.join(out_dir, f"{name}.wbin"), _np(params))
        summary[name] = ppl
        log(f"{name}: train ppl {ppl:.2f}")
    return summary


def _np(params):
    return {k: np.asarray(v) for k, v in params.items()}
