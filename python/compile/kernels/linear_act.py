"""Pallas kernel: fused producer forward `gelu(x·Wᵀ + b)`.

The producer layer's matmul, bias and activation execute in one VMEM
round trip — the fusion the paper's calibration pass relies on (the
consumer-input activations are exactly this kernel's output). Grid
`(i, j, k)`; bias-add and GELU run on the final reduction step only.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_M = 128
BLOCK_N = 128
BLOCK_K = 128

_GELU_C = 0.7978845608028654  # sqrt(2/pi)


def _linear_gelu_kernel(x_ref, wt_ref, b_ref, o_ref, *, k_steps):
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], wt_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k_step == k_steps - 1)
    def _finish():
        y = o_ref[...] + b_ref[...]
        o_ref[...] = 0.5 * y * (1.0 + jnp.tanh(_GELU_C * (y + 0.044715 * y**3)))


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def linear_gelu(x, w, b, *, bm: int = BLOCK_M, bn: int = BLOCK_N, bk: int = BLOCK_K):
    """`gelu(x Wᵀ + b)` for `x: [m, k]`, `w: [n, k]`, `b: [n]`.

    Shapes must tile evenly; `linear_gelu_padded` pads otherwise.
    """
    m, k = x.shape
    n, k2 = w.shape
    if k != k2:
        raise ValueError(f"linear_gelu: inner dims {k} vs {k2}")
    bm = min(bm, m)
    bn = min(bn, n)
    bk = min(bk, k)
    if m % bm or n % bn or k % bk:
        raise ValueError(f"linear_gelu: ({m},{k},{n}) not divisible")
    wt = w.T  # [k, n]
    b2 = b.reshape(1, n)
    grid = (m // bm, n // bn, k // bk)
    kernel = functools.partial(_linear_gelu_kernel, k_steps=k // bk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, s: (i, s)),
            pl.BlockSpec((bk, bn), lambda i, j, s: (s, j)),
            pl.BlockSpec((1, bn), lambda i, j, s: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, wt, b2)


def linear_gelu_padded(x, w, b, *, bm: int = BLOCK_M, bn: int = BLOCK_N, bk: int = BLOCK_K):
    """`gelu(x Wᵀ + b)` for arbitrary shapes via zero padding."""
    m, k = x.shape
    n, _ = w.shape
    bm = min(bm, max(m, 1))
    bn = min(bn, max(n, 1))
    bk = min(bk, max(k, 1))
    mp, np_, kp = (-m) % bm, (-n) % bn, (-k) % bk
    if mp or kp:
        x = jnp.pad(x, ((0, mp), (0, kp)))
    if np_ or kp:
        w = jnp.pad(w, ((0, np_), (0, kp)))
    if np_:
        b = jnp.pad(b, (0, np_))
    y = linear_gelu(x, w, b, bm=bm, bn=bn, bk=bk)
    return y[:m, :n]
