"""Pallas kernel: tiled Gram-matrix accumulation `G = XᵀX`.

This is the paper's calibration hot spot (§3 Complexity: O(N·H²)). The
TPU formulation tiles `X: [N, H]` into `[BN, BH]` VMEM blocks on a 3-D
grid `(i, j, n)`; each step multiplies an `[BN, BHi]` block transposed
against an `[BN, BHj]` block on the MXU and accumulates into the
`(i, j)` output tile across the reduction axis `n` (grid-carried
revisiting, the standard Pallas reduction idiom).

Hardware adaptation (DESIGN.md §3): the paper ran on A100s where this
is a cuBLAS syrk; on TPU the same computation is expressed as an
MXU-tiled matmul with the HBM↔VMEM schedule in the BlockSpecs below.
`interpret=True` everywhere — the CPU PJRT plugin cannot execute Mosaic
custom-calls; real-TPU numbers are estimated from the block geometry in
EXPERIMENTS.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default block sizes: 128 matches the MXU systolic array edge; the
# working set per step is 2·BN·BH + BH·BH floats = 3·128·128·4B ≈ 196 KiB,
# comfortably inside a TPU core's ~16 MiB VMEM with room for
# double-buffering.
BLOCK_N = 128
BLOCK_H = 128


def _gram_kernel(x_i_ref, x_j_ref, g_ref):
    """One grid step: accumulate `x_iᵀ · x_j` into the (i, j) tile."""
    n_step = pl.program_id(2)

    @pl.when(n_step == 0)
    def _init():
        g_ref[...] = jnp.zeros_like(g_ref)

    xi = x_i_ref[...]  # [BN, BHi]
    xj = x_j_ref[...]  # [BN, BHj]
    g_ref[...] += jax.lax.dot_general(
        xi,
        xj,
        dimension_numbers=(((0,), (0,)), ((), ())),  # contract over rows
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("block_n", "block_h"))
def gram(x, *, block_n: int = BLOCK_N, block_h: int = BLOCK_H):
    """`XᵀX` for `x: [n, h]` via the tiled Pallas kernel.

    Shapes must tile evenly; `gram_padded` handles the general case.
    """
    n, h = x.shape
    bn = min(block_n, n)
    bh = min(block_h, h)
    if n % bn or h % bh:
        raise ValueError(f"gram: ({n},{h}) not divisible by blocks ({bn},{bh})")
    grid = (h // bh, h // bh, n // bn)
    return pl.pallas_call(
        _gram_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bh), lambda i, j, k: (k, i)),
            pl.BlockSpec((bn, bh), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bh, bh), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((h, h), jnp.float32),
        interpret=True,  # CPU-PJRT cannot run Mosaic custom-calls
    )(x, x)


def gram_padded(x, *, block_n: int = BLOCK_N, block_h: int = BLOCK_H):
    """`XᵀX` for arbitrary shapes: zero-pad rows/cols to the block grid
    (zero rows contribute nothing to the Gram; padded columns are
    sliced away)."""
    n, h = x.shape
    bn = min(block_n, max(n, 1))
    bh = min(block_h, max(h, 1))
    n_pad = (-n) % bn
    h_pad = (-h) % bh
    if n_pad or h_pad:
        x = jnp.pad(x, ((0, n_pad), (0, h_pad)))
    g = gram(x, block_n=bn, block_h=bh)
    return g[:h, :h]
