"""Pallas kernel: blocked matmul `C = A·B` (MXU-tiled).

Used by the L2 model graphs for their dense projections so the whole
forward lowers through the same kernel machinery as the Gram
accumulation. Grid `(i, j, k)` with the `k` axis reducing into a
grid-carried accumulator tile.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_M = 128
BLOCK_N = 128
BLOCK_K = 128


def _matmul_kernel(a_ref, b_ref, c_ref):
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        c_ref[...] = jnp.zeros_like(c_ref)

    c_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul(a, b, *, bm: int = BLOCK_M, bn: int = BLOCK_N, bk: int = BLOCK_K):
    """`a @ b` for `a: [m, k]`, `b: [k, n]`; shapes must tile evenly
    (`matmul_padded` pads otherwise)."""
    m, k = a.shape
    k2, n = b.shape
    if k != k2:
        raise ValueError(f"matmul: inner dims {k} vs {k2}")
    bm = min(bm, m)
    bn = min(bn, n)
    bk = min(bk, k)
    if m % bm or n % bn or k % bk:
        raise ValueError(f"matmul: ({m},{k},{n}) not divisible by ({bm},{bk},{bn})")
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, s: (i, s)),
            pl.BlockSpec((bk, bn), lambda i, j, s: (s, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(a, b)


def matmul_padded(a, b, *, bm: int = BLOCK_M, bn: int = BLOCK_N, bk: int = BLOCK_K):
    """`a @ b` for arbitrary shapes via zero padding to the block grid."""
    m, k = a.shape
    _, n = b.shape
    bm = min(bm, max(m, 1))
    bn = min(bn, max(n, 1))
    bk = min(bk, max(k, 1))
    mp, np_, kp = (-m) % bm, (-n) % bn, (-k) % bk
    if mp or kp:
        a = jnp.pad(a, ((0, mp), (0, kp)))
    if kp or np_:
        b = jnp.pad(b, ((0, kp), (0, np_)))
    c = matmul(a, b, bm=bm, bn=bn, bk=bk)
    return c[:m, :n]
