"""Pure-jnp reference oracles for the Pallas kernels.

These are the correctness ground truth: every Pallas kernel in this
package is checked against its `ref_*` twin by `python/tests/` (exact
shapes via pytest, randomized shape/value sweeps via hypothesis).
"""

import jax.numpy as jnp


def ref_gram(x):
    """Uncentered second moment `XᵀX` of `x: [n, h]` (paper §3.2,
    `G = Σ x xᵀ`)."""
    return x.T @ x


def ref_matmul(a, b):
    """Plain matmul `a @ b`."""
    return a @ b


def ref_linear_gelu(x, w, b):
    """Fused producer forward `gelu(x Wᵀ + b)` with the tanh GELU
    (matches `jax.nn.gelu(approximate=True)` and the Rust
    `nn::gelu_scalar`)."""
    y = x @ w.T + b
    c = 0.7978845608028654  # sqrt(2/pi)
    return 0.5 * y * (1.0 + jnp.tanh(c * (y + 0.044715 * y**3)))


def ref_ridge_reconstruction(gram, keep, lam):
    """GRAIL pruning reconstruction `B = G[:,P] (G[P,P] + λI)⁻¹` — used
    to cross-check the Rust Cholesky path end-to-end."""
    g_ph = gram[keep, :]  # [K, H]
    g_pp = g_ph[:, keep]  # [K, K]
    k = g_pp.shape[0]
    sol = jnp.linalg.solve(g_pp + lam * jnp.eye(k, dtype=gram.dtype), g_ph)
    return sol.T  # [H, K]
